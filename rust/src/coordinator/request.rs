//! Request/response types of the serving API.

use super::builder::BackendCell;
use crate::fixed::AccuracyClass;
use crate::graph::VertexId;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Name routed to when a request does not pick a graph — the implicit
/// single graph of [`super::server::Server::start`]-style servers, and the
/// back-compat default for registry-backed servers with no explicit
/// default.
pub const DEFAULT_GRAPH: &str = "default";

/// The shared key for [`DEFAULT_GRAPH`]: one allocation per process, so
/// building a request costs no heap traffic on the steady-state serving
/// path.
pub fn default_graph_key() -> Arc<str> {
    static KEY: std::sync::OnceLock<Arc<str>> = std::sync::OnceLock::new();
    KEY.get_or_init(|| Arc::from(DEFAULT_GRAPH)).clone()
}

/// A single PPR query: "rank vertices for this personalization vertex on
/// this graph".
#[derive(Debug, Clone)]
pub struct PprRequest {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// The graph this query runs on. Requests never batch across graphs
    /// (one personalization space per batch — DESIGN.md §6).
    pub graph: Arc<str>,
    /// The accuracy class this query runs under (DESIGN.md §7). Requests
    /// never batch across classes — a batch is one graph × one ladder.
    pub class: AccuracyClass,
    /// Personalization vertex.
    pub vertex: VertexId,
    /// How many top-ranked vertices to return.
    pub top_n: usize,
    /// Optional completion deadline; requests that expire in the queue are
    /// failed fast instead of occupying an accelerator lane.
    pub deadline: Option<Instant>,
    /// Submission timestamp (set by the server on enqueue).
    pub enqueued_at: Instant,
    /// The backend that actually solved this request, stamped by the
    /// serving worker (shared with the submitter's `Ticket` — under
    /// dispatch the backend is a runtime routing decision, DESIGN.md
    /// §12).
    pub served_by: BackendCell,
}

impl PprRequest {
    /// Build a request for the [`DEFAULT_GRAPH`] (enqueue time is stamped
    /// now, no deadline).
    pub fn new(id: u64, vertex: VertexId, top_n: usize) -> Self {
        Self {
            id,
            graph: default_graph_key(),
            class: AccuracyClass::Static,
            vertex,
            top_n,
            deadline: None,
            enqueued_at: Instant::now(),
            served_by: BackendCell::new(),
        }
    }

    /// Route the request to a named graph.
    pub fn with_graph(mut self, graph: Arc<str>) -> Self {
        self.graph = graph;
        self
    }

    /// Run the request under an accuracy class.
    pub fn with_class(mut self, class: AccuracyClass) -> Self {
        self.class = class;
        self
    }

    /// Attach a completion deadline.
    pub fn with_deadline(mut self, deadline: Option<Instant>) -> Self {
        self.deadline = deadline;
        self
    }

    /// Whether the deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One ranked result row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RankedVertex {
    /// Vertex id.
    pub vertex: VertexId,
    /// PPR score (dequantized).
    pub score: f64,
}

/// The response to a [`PprRequest`].
#[derive(Debug, Clone, PartialEq)]
pub struct PprResponse {
    /// Echo of the request id.
    pub id: u64,
    /// The graph the query ran on.
    pub graph: Arc<str>,
    /// The accuracy class the query ran under.
    pub class: AccuracyClass,
    /// Echo of the personalization vertex.
    pub vertex: VertexId,
    /// Top-N vertices, descending score.
    pub ranking: Vec<RankedVertex>,
    /// PPR iterations the batch executed.
    pub iterations: usize,
    /// Precision-ladder rung escalations the batch took (rungs − 1; zero
    /// for single-rung/static engines). Exposed per-class by `/metrics`.
    pub escalations: usize,
    /// Queue wait (enqueue → batch formation).
    pub queue_time: Duration,
    /// Total latency (enqueue → response).
    pub total_time: Duration,
    /// True when the response was produced by the degradation policy (a
    /// retry on a narrower accuracy class or the CPU-baseline backend
    /// after the requested engine failed) rather than the requested
    /// engine. The HTTP layer only serializes the field when set, so
    /// fault-free responses are byte-identical to servers without the
    /// policy.
    pub degraded: bool,
}

/// A typed serving failure — everything that can go wrong **after** a
/// request passes validation: queue/deadline expiry, routing misses,
/// engine faults (errors and contained panics), worker death, exhausted
/// degradation retries, and shutdown races. The HTTP layer maps status
/// codes from [`ServeError::status`] instead of matching substrings of
/// error text, and the `Display` strings stay client-presentable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The deadline passed while the request waited in the batcher queue.
    DeadlineQueue,
    /// The deadline passed while the solve was running.
    DeadlineSolve,
    /// The deadline passed while the caller waited on the ticket.
    DeadlineWait,
    /// The named graph is not registered. `single` marks single-graph
    /// servers, which only route [`DEFAULT_GRAPH`].
    GraphUnknown {
        /// The graph name the request asked for.
        name: String,
        /// True on single-graph servers (different client remedy).
        single: bool,
    },
    /// A routed request reached a registry server with no default graph.
    NoDefaultGraph,
    /// The personalization vertex is outside the graph's vertex range.
    /// `after_reload` marks the race where a hot-swap shrank |V| after
    /// submission validated the vertex.
    VertexOutOfRange {
        /// The offending vertex id.
        vertex: u64,
        /// |V| at rejection time.
        num_vertices: usize,
        /// True when the range check failed post-reload at serve time.
        after_reload: bool,
    },
    /// The engine returned an error from the solve.
    EngineFailed(String),
    /// The engine panicked; the panic was contained and the worker keeps
    /// serving.
    EnginePanicked(String),
    /// The worker thread died while this request's batch was in flight;
    /// the watchdog fails pending tickets promptly instead of letting
    /// them hang to their deadlines.
    WorkerDied,
    /// The registry could not resolve/prepare the graph for this batch.
    GraphUnavailable {
        /// The graph name.
        name: String,
        /// The resolution failure.
        reason: String,
    },
    /// The degradation policy's retry also failed.
    DegradedExhausted(String),
    /// The circuit breaker for this `(graph, class)` is open; retry after
    /// the embedded hint.
    BreakerOpen {
        /// Suggested client back-off in milliseconds.
        retry_after_ms: u64,
    },
    /// The response channel disconnected without a response (server
    /// dropped mid-flight).
    ChannelClosed,
    /// The server is shutting down and no longer accepts work.
    ShuttingDown,
}

impl ServeError {
    /// The HTTP status this failure maps to. Kept next to the taxonomy so
    /// the HTTP layer never interprets error *text*.
    pub fn status(&self) -> u16 {
        match self {
            ServeError::DeadlineQueue | ServeError::DeadlineSolve | ServeError::DeadlineWait => {
                504
            }
            ServeError::GraphUnknown { .. } | ServeError::NoDefaultGraph => 404,
            ServeError::VertexOutOfRange { .. } => 400,
            ServeError::BreakerOpen { .. } | ServeError::ShuttingDown => 503,
            ServeError::EngineFailed(_)
            | ServeError::EnginePanicked(_)
            | ServeError::WorkerDied
            | ServeError::GraphUnavailable { .. }
            | ServeError::DegradedExhausted(_)
            | ServeError::ChannelClosed => 500,
        }
    }

    /// True for failures that should trip the circuit breaker: genuine
    /// engine/worker faults, not client errors, overload shed, or
    /// deadline expiry.
    pub fn is_fault(&self) -> bool {
        matches!(
            self,
            ServeError::EngineFailed(_)
                | ServeError::EnginePanicked(_)
                | ServeError::WorkerDied
                | ServeError::GraphUnavailable { .. }
                | ServeError::DegradedExhausted(_)
                | ServeError::ChannelClosed
        )
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineQueue => write!(f, "deadline exceeded in queue"),
            ServeError::DeadlineSolve => write!(f, "deadline exceeded during solve"),
            ServeError::DeadlineWait => {
                write!(f, "deadline exceeded waiting for response")
            }
            ServeError::GraphUnknown { name, single: false } => {
                write!(f, "unknown graph {name}")
            }
            ServeError::GraphUnknown { name, single: true } => {
                write!(f, "unknown graph {name} (single-graph server)")
            }
            ServeError::NoDefaultGraph => write!(f, "no default graph registered"),
            ServeError::VertexOutOfRange { vertex, num_vertices, after_reload: false } => {
                write!(f, "vertex {vertex} out of range (|V|={num_vertices})")
            }
            ServeError::VertexOutOfRange { vertex, num_vertices, after_reload: true } => {
                write!(f, "vertex {vertex} out of range (|V|={num_vertices} after reload)")
            }
            ServeError::EngineFailed(e) => write!(f, "engine error: {e}"),
            ServeError::EnginePanicked(msg) => write!(f, "engine panicked: {msg}"),
            ServeError::WorkerDied => write!(f, "worker died with the batch in flight"),
            ServeError::GraphUnavailable { name, reason } => {
                write!(f, "graph {name} unavailable: {reason}")
            }
            ServeError::DegradedExhausted(e) => {
                write!(f, "degraded retry exhausted: {e}")
            }
            ServeError::BreakerOpen { retry_after_ms } => {
                write!(f, "circuit breaker open (retry in {retry_after_ms}ms)")
            }
            ServeError::ChannelClosed => write!(f, "response channel closed"),
            ServeError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A typed rejection of a malformed query, raised **before** anything is
/// enqueued. The HTTP handlers map every variant to a 400; keeping the
/// taxonomy here (not in the HTTP layer) means the in-process API rejects
/// the same inputs the same way, and the core can never be panicked by
/// client-controlled values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryError {
    /// The personalization set was empty.
    EmptyPersonalization,
    /// `top_n` was 0 with no server default to fall back to.
    ZeroTopN,
    /// The accuracy-class string matched no known class.
    UnknownClass(String),
    /// A personalization vertex is outside `[0, num_vertices)`.
    VertexOutOfRange {
        /// The offending vertex id.
        vertex: u64,
        /// The graph's vertex count at validation time.
        num_vertices: usize,
    },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::EmptyPersonalization => {
                write!(f, "personalization set must not be empty")
            }
            QueryError::ZeroTopN => write!(f, "top_n must be at least 1"),
            QueryError::UnknownClass(s) => {
                write!(f, "unknown accuracy class {s:?} (expected static|fast|balanced|exact)")
            }
            QueryError::VertexOutOfRange { vertex, num_vertices } => {
                write!(f, "vertex {vertex} out of range (|V|={num_vertices})")
            }
        }
    }
}

impl std::error::Error for QueryError {}

/// Validate the JSON-facing query fields against a graph of
/// `num_vertices` vertices. `class` is the raw client string (`None`
/// means "use the server default"). Returns the parsed class and the
/// **effective** `top_n` on success: `top_n == 0` is a typed rejection
/// ([`QueryError::ZeroTopN`] → HTTP 400) and `top_n > |V|` is clamped to
/// `|V|` — a ranking can never hold more rows than the graph has
/// vertices, and clamping here keeps the serving layers (and the
/// top-K-native routing cap) working with a meaningful K. Vertex ids
/// arrive as `u64` (straight from the JSON number) so an id beyond `u32`
/// is a range error, never a silent truncation.
pub fn validate_query(
    vertices: &[u64],
    top_n: usize,
    class: Option<&str>,
    num_vertices: usize,
) -> Result<(Option<AccuracyClass>, usize), QueryError> {
    if vertices.is_empty() {
        return Err(QueryError::EmptyPersonalization);
    }
    if top_n == 0 {
        return Err(QueryError::ZeroTopN);
    }
    let parsed = match class {
        None => None,
        Some(s) => Some(
            AccuracyClass::parse(s).ok_or_else(|| QueryError::UnknownClass(s.to_string()))?,
        ),
    };
    for &v in vertices {
        if v >= num_vertices as u64 {
            return Err(QueryError::VertexOutOfRange { vertex: v, num_vertices });
        }
    }
    Ok((parsed, top_n.min(num_vertices)))
}

/// Extract the top-N ranking from a dense lane of scores: descending
/// score, ties toward the lower vertex id, NaN never outranking a number.
/// `top_n` is clamped to the lane length; `top_n == 0` yields an empty
/// ranking. (Serving-path extraction goes through
/// [`super::score_block::ScoreBlock::top_n`], which shares this kernel.)
pub fn rank_top_n(scores: &[f64], top_n: usize) -> Vec<RankedVertex> {
    crate::metrics::top_n_indices_f64(scores, top_n)
        .into_iter()
        .map(|v| RankedVertex { vertex: v as VertexId, score: scores[v] })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_top_n_orders() {
        let scores = [0.1, 0.5, 0.3];
        let r = rank_top_n(&scores, 2);
        assert_eq!(r[0], RankedVertex { vertex: 1, score: 0.5 });
        assert_eq!(r[1], RankedVertex { vertex: 2, score: 0.3 });
    }

    #[test]
    fn rank_top_n_breaks_ties_toward_lower_id() {
        let scores = [0.5, 0.9, 0.5, 0.9];
        let r: Vec<u32> = rank_top_n(&scores, 4).iter().map(|x| x.vertex).collect();
        assert_eq!(r, vec![1, 3, 0, 2]);
    }

    #[test]
    fn rank_top_n_demotes_nan() {
        let scores = [f64::NAN, 0.4, 0.9, f64::NAN];
        let r = rank_top_n(&scores, 3);
        assert_eq!(r[0].vertex, 2);
        assert_eq!(r[1].vertex, 1);
        assert!(r[2].score.is_nan(), "NaN fills the tail, never the head");
    }

    #[test]
    fn rank_top_n_clamps_and_zero() {
        let scores = [0.3, 0.1];
        assert_eq!(rank_top_n(&scores, 10).len(), 2, "top_n > |V| clamps");
        assert!(rank_top_n(&scores, 0).is_empty());
        assert!(rank_top_n(&[], 5).is_empty(), "empty lane yields empty ranking");
    }

    #[test]
    fn request_stamps_time() {
        let r = PprRequest::new(1, 2, 10);
        assert!(r.enqueued_at.elapsed() < Duration::from_secs(1));
        assert!(r.deadline.is_none());
        assert_eq!(r.graph.as_ref(), DEFAULT_GRAPH, "unrouted requests take the default graph");
        let r2 = PprRequest::new(2, 3, 10);
        assert!(
            Arc::ptr_eq(&r.graph, &r2.graph),
            "the default key is one shared allocation, not one per request"
        );
    }

    #[test]
    fn request_routes_to_named_graph() {
        let key: Arc<str> = Arc::from("eu-market");
        let r = PprRequest::new(7, 3, 5).with_graph(key.clone());
        assert_eq!(r.graph.as_ref(), "eu-market");
        assert!(Arc::ptr_eq(&r.graph, &key), "interned key is shared, not copied");
    }

    #[test]
    fn request_carries_accuracy_class() {
        let r = PprRequest::new(1, 2, 10);
        assert_eq!(r.class, AccuracyClass::Static, "unclassed requests stay static");
        let r = r.with_class(AccuracyClass::Balanced);
        assert_eq!(r.class, AccuracyClass::Balanced);
    }

    #[test]
    fn validate_query_rejects_empty_personalization() {
        assert_eq!(
            validate_query(&[], 5, None, 100),
            Err(QueryError::EmptyPersonalization)
        );
    }

    #[test]
    fn validate_query_rejects_zero_top_n() {
        assert_eq!(validate_query(&[1], 0, None, 100), Err(QueryError::ZeroTopN));
    }

    #[test]
    fn validate_query_rejects_unknown_class_strings() {
        for bad in ["turbo", "", "EXACTLY", "fast ish"] {
            assert_eq!(
                validate_query(&[1], 5, Some(bad), 100),
                Err(QueryError::UnknownClass(bad.to_string())),
                "{bad:?}"
            );
        }
        // canonical labels and whitespace/case variants parse
        for class in AccuracyClass::all() {
            assert_eq!(
                validate_query(&[1], 5, Some(class.label()), 100),
                Ok((Some(class), 5))
            );
        }
        assert_eq!(
            validate_query(&[1], 5, Some(" Exact "), 100),
            Ok((Some(AccuracyClass::Exact), 5))
        );
        assert_eq!(validate_query(&[1], 5, None, 100), Ok((None, 5)), "absent class → default");
    }

    #[test]
    fn validate_query_clamps_oversized_top_n() {
        // top_n beyond |V| can never be honored: the effective value is
        // clamped so downstream layers (including the top-K routing cap)
        // see a meaningful K
        assert_eq!(validate_query(&[1], 5_000, None, 100), Ok((None, 100)));
        assert_eq!(validate_query(&[1], 100, None, 100), Ok((None, 100)), "boundary passes");
        assert_eq!(validate_query(&[1], 99, None, 100), Ok((None, 99)));
    }

    #[test]
    fn validate_query_rejects_out_of_range_vertices() {
        assert_eq!(
            validate_query(&[0, 99, 100], 5, None, 100),
            Err(QueryError::VertexOutOfRange { vertex: 100, num_vertices: 100 })
        );
        // ids beyond u32 are a range error, never a truncation
        let huge = u64::from(u32::MAX) + 7;
        assert_eq!(
            validate_query(&[huge], 5, None, 100),
            Err(QueryError::VertexOutOfRange { vertex: huge, num_vertices: 100 })
        );
        assert!(validate_query(&[0, 99], 5, None, 100).is_ok());
        // errors format into client-presentable strings
        let msg = validate_query(&[100], 5, None, 100).unwrap_err().to_string();
        assert!(msg.contains("out of range"), "{msg}");
    }

    #[test]
    fn serve_error_statuses_and_messages() {
        use ServeError::*;
        assert_eq!(DeadlineQueue.status(), 504);
        assert_eq!(DeadlineSolve.status(), 504);
        assert_eq!(DeadlineWait.status(), 504);
        assert_eq!(GraphUnknown { name: "x".into(), single: false }.status(), 404);
        assert_eq!(NoDefaultGraph.status(), 404);
        assert_eq!(
            VertexOutOfRange { vertex: 9, num_vertices: 4, after_reload: false }.status(),
            400
        );
        assert_eq!(BreakerOpen { retry_after_ms: 100 }.status(), 503);
        assert_eq!(ShuttingDown.status(), 503);
        for e in [
            EngineFailed("boom".into()),
            EnginePanicked("boom".into()),
            WorkerDied,
            GraphUnavailable { name: "g".into(), reason: "r".into() },
            DegradedExhausted("boom".into()),
            ChannelClosed,
        ] {
            assert_eq!(e.status(), 500, "{e}");
            assert!(e.is_fault(), "{e} trips the breaker");
        }
        assert!(!DeadlineQueue.is_fault(), "deadline misses are load, not faults");
        assert!(!BreakerOpen { retry_after_ms: 1 }.is_fault());

        // the Display strings are the wire-visible contract
        assert_eq!(DeadlineQueue.to_string(), "deadline exceeded in queue");
        assert_eq!(DeadlineSolve.to_string(), "deadline exceeded during solve");
        assert_eq!(DeadlineWait.to_string(), "deadline exceeded waiting for response");
        assert_eq!(
            GraphUnknown { name: "eu".into(), single: false }.to_string(),
            "unknown graph eu"
        );
        assert_eq!(
            GraphUnknown { name: "eu".into(), single: true }.to_string(),
            "unknown graph eu (single-graph server)"
        );
        assert_eq!(
            VertexOutOfRange { vertex: 7, num_vertices: 5, after_reload: false }.to_string(),
            "vertex 7 out of range (|V|=5)"
        );
        assert_eq!(
            VertexOutOfRange { vertex: 7, num_vertices: 5, after_reload: true }.to_string(),
            "vertex 7 out of range (|V|=5 after reload)"
        );
        assert_eq!(ChannelClosed.to_string(), "response channel closed");
        assert_eq!(ShuttingDown.to_string(), "server shutting down");
    }

    #[test]
    fn request_deadline_expiry() {
        let now = Instant::now();
        let r = PprRequest::new(1, 2, 10).with_deadline(Some(now + Duration::from_secs(60)));
        assert!(!r.expired(now));
        assert!(r.expired(now + Duration::from_secs(61)));
        assert!(r.expired(now + Duration::from_secs(60)), "boundary counts as expired");
        assert!(!PprRequest::new(1, 2, 10).expired(now + Duration::from_secs(3600)));
    }
}
