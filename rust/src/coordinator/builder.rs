//! Engine construction registry: `EngineKind` × [`RunConfig`] →
//! `Box<dyn PprEngine + Send>`.
//!
//! The seed grew three hand-rolled construction paths (CLI, bench harness,
//! examples), each wiring precision/κ/graph prep slightly differently.
//! [`EngineBuilder`] is now the single factory every front-end goes
//! through: it owns graph preparation (packet schedule for the streaming
//! backends, CSR for the CPU baseline), backend-specific spawn logic (PJRT
//! engines are thread-affine and come back pre-wrapped in
//! [`ThreadBoundEngine`]), worker-pool fan-out, and the one-call
//! [`EngineBuilder::serve`] that stands up a whole [`Server`].

use super::engine::{
    CpuBaselineEngine, LadderEngine, NativeEngine, PjrtEngineAdapter, PprEngine,
    ThreadBoundEngine,
};
use super::registry::{GraphEntry, GraphRegistry};
use super::server::{Server, ServerConfig};
use crate::config::RunConfig;
use crate::fault::FaultPlan;
use crate::fixed::AccuracyClass;
use crate::graph::{CsrMatrix, Graph};
use crate::ppr::PreparedGraph;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Which backend an engine runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Native Rust engine (bit-accurate model of the FPGA datapath).
    Native,
    /// PJRT execution of the AOT JAX/Pallas artifacts (thread-bound).
    Pjrt,
    /// Multi-threaded f32 CPU baseline (the paper's PGX stand-in).
    CpuBaseline,
}

impl EngineKind {
    /// Parse a CLI/config label.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            "cpu" | "cpu-baseline" | "baseline" => Some(EngineKind::CpuBaseline),
            _ => None,
        }
    }

    /// Canonical label.
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
            EngineKind::CpuBaseline => "cpu-baseline",
        }
    }

    /// Every backend, native first.
    pub fn all() -> [EngineKind; 3] {
        [EngineKind::Native, EngineKind::Pjrt, EngineKind::CpuBaseline]
    }

    /// Compact wire encoding (for [`BackendCell`]; 0 means "unset").
    pub fn as_u8(&self) -> u8 {
        match self {
            EngineKind::Native => 1,
            EngineKind::Pjrt => 2,
            EngineKind::CpuBaseline => 3,
        }
    }

    /// Decode [`Self::as_u8`]; 0 (and anything unknown) is `None`.
    pub fn from_u8(v: u8) -> Option<EngineKind> {
        match v {
            1 => Some(EngineKind::Native),
            2 => Some(EngineKind::Pjrt),
            3 => Some(EngineKind::CpuBaseline),
            _ => None,
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// A shared write-once-per-serve slot recording which backend actually
/// served a request — stamped by the worker just before the solve,
/// readable from the request's [`Ticket`](super::server::Ticket) after
/// the response lands. Under dispatch the serving backend is a runtime
/// decision (routing, stealing, degrade), so attribution can't ride the
/// request by value.
#[derive(Debug, Clone, Default)]
pub struct BackendCell(Arc<std::sync::atomic::AtomicU8>);

impl BackendCell {
    /// New unset cell.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the serving backend (last write wins — a degraded retry
    /// overwrites the failed attempt's stamp).
    pub fn set(&self, kind: EngineKind) {
        self.0.store(kind.as_u8(), std::sync::atomic::Ordering::Release);
    }

    /// The recorded backend, if any solve ran.
    pub fn get(&self) -> Option<EngineKind> {
        EngineKind::from_u8(self.0.load(std::sync::atomic::Ordering::Acquire))
    }
}

/// Builder for serving engines; see the module docs.
#[derive(Debug, Clone)]
pub struct EngineBuilder {
    kind: EngineKind,
    cfg: RunConfig,
    artifact_label: Option<String>,
    fault: Option<Arc<FaultPlan>>,
}

impl EngineBuilder {
    /// Builder for `kind` with the default [`RunConfig`].
    pub fn new(kind: EngineKind) -> Self {
        Self { kind, cfg: RunConfig::default(), artifact_label: None, fault: None }
    }

    /// Shorthand for [`EngineKind::Native`].
    pub fn native() -> Self {
        Self::new(EngineKind::Native)
    }

    /// Shorthand for [`EngineKind::Pjrt`].
    pub fn pjrt() -> Self {
        Self::new(EngineKind::Pjrt)
    }

    /// Shorthand for [`EngineKind::CpuBaseline`].
    pub fn cpu_baseline() -> Self {
        Self::new(EngineKind::CpuBaseline)
    }

    /// Set the run configuration (precision, κ, iterations, α, …).
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Override the AOT artifact label for PJRT engines (defaults to the
    /// configured precision's label, e.g. `26b`).
    pub fn artifact_label(mut self, label: impl Into<String>) -> Self {
        self.artifact_label = Some(label.into());
        self
    }

    /// Attach (or clear) a deterministic fault-injection plan
    /// (DESIGN.md §10): servers stood up through [`Self::serve`] /
    /// [`Self::serve_registry`] carry it into their workers. `None` — the
    /// default — keeps the production hot path.
    pub fn fault(mut self, plan: Option<Arc<FaultPlan>>) -> Self {
        self.fault = plan;
        self
    }

    /// The backend this builder targets.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// The same builder retargeted at another backend — how dispatch
    /// worker groups derive their per-backend builders from the one
    /// configured builder (config, faults and artifact label carry over).
    pub fn with_kind(&self, kind: EngineKind) -> Self {
        Self { kind, ..self.clone() }
    }

    /// The configuration this builder applies.
    pub fn run_config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Build one engine over a raw graph (preprocessing done here).
    pub fn build(&self, graph: &Graph) -> Result<Box<dyn PprEngine + Send>> {
        self.cfg.validate()?;
        match self.kind {
            EngineKind::CpuBaseline => {
                let csr = Arc::new(CsrMatrix::from_graph(graph));
                Ok(Box::new(CpuBaselineEngine::new(csr, self.cfg.clone())))
            }
            _ => self.build_prepared(Arc::new(self.prepare(graph, 1))),
        }
    }

    /// Shard count of the preparation this builder performs for a pool of
    /// `workers`: the configured count applies only to the native engine
    /// (the PJRT marshaller reads the single stream, so sharded
    /// preparation would be wasted work and memory) and is divided among
    /// the pool's workers so concurrent batches don't oversubscribe the
    /// host (each worker fans out over its own engine's shards).
    pub fn prep_shards(&self, workers: usize) -> usize {
        match self.kind {
            EngineKind::Native => (self.cfg.num_shards / workers.max(1)).max(1),
            _ => 1,
        }
    }

    /// Graph preparation this builder performs: packet width from the run
    /// configuration, shard count from [`Self::prep_shards`].
    fn prepare(&self, graph: &Graph, workers: usize) -> PreparedGraph {
        PreparedGraph::new_sharded(graph, self.cfg.b, self.prep_shards(workers))
    }

    /// Build one engine over an already-prepared packet schedule (shared
    /// across a pool; not applicable to the CSR-based CPU baseline). The
    /// prepared graph's shard count applies, not the configuration's. A
    /// native builder whose configuration selects a ladder class
    /// (`engine.accuracy_class` / `--class`) yields a [`LadderEngine`].
    pub fn build_prepared(&self, prepared: Arc<PreparedGraph>) -> Result<Box<dyn PprEngine + Send>> {
        self.cfg.validate()?;
        match self.kind {
            EngineKind::Native => {
                if self.cfg.accuracy_class.ladder().is_some() {
                    Ok(Box::new(LadderEngine::new(
                        prepared,
                        self.cfg.accuracy_class,
                        &self.cfg,
                    )?))
                } else {
                    Ok(Box::new(NativeEngine::new(prepared, self.cfg.clone())))
                }
            }
            EngineKind::Pjrt => self.spawn_pjrt(prepared),
            EngineKind::CpuBaseline => anyhow::bail!(
                "cpu-baseline builds from the raw graph; use EngineBuilder::build"
            ),
        }
    }

    /// Build a pool of `workers` engines sharing one graph preparation.
    pub fn build_pool(
        &self,
        graph: &Graph,
        workers: usize,
    ) -> Result<Vec<Box<dyn PprEngine + Send>>> {
        anyhow::ensure!(workers >= 1, "need at least one worker");
        self.cfg.validate()?;
        match self.kind {
            EngineKind::CpuBaseline => {
                let csr = Arc::new(CsrMatrix::from_graph(graph));
                Ok((0..workers)
                    .map(|_| {
                        Box::new(CpuBaselineEngine::new(csr.clone(), self.cfg.clone()))
                            as Box<dyn PprEngine + Send>
                    })
                    .collect())
            }
            _ => {
                let prepared = Arc::new(self.prepare(graph, workers));
                (0..workers).map(|_| self.build_prepared(prepared.clone())).collect()
            }
        }
    }

    /// Build one engine over a resolved registry entry (the registry
    /// serving path: native/PJRT bind the entry's prepared schedule, the
    /// CPU baseline its lazily-derived CSR), under the configuration's
    /// own accuracy class.
    pub fn build_entry(&self, entry: &GraphEntry) -> Result<Box<dyn PprEngine + Send>> {
        self.build_entry_class(entry, self.cfg.accuracy_class)
    }

    /// Build the engine an accuracy class runs on, over a resolved
    /// registry entry. The class is authoritative (a `Static` request on
    /// a ladder-default server still gets the static engine): ladder
    /// classes get a native [`LadderEngine`] whose rung streams come from
    /// the entry's per-precision cache; `Static` — and backends without a
    /// ladder implementation (PJRT artifacts are synthesized per width,
    /// the CPU baseline is f32-only) — get the static engine of the
    /// configured precision, its value streams also from the entry's
    /// cache so worker replicas share one quantized copy (DESIGN.md §7).
    pub fn build_entry_class(
        &self,
        entry: &GraphEntry,
        class: AccuracyClass,
    ) -> Result<Box<dyn PprEngine + Send>> {
        self.cfg.validate()?;
        match self.kind {
            EngineKind::CpuBaseline => {
                Ok(Box::new(CpuBaselineEngine::new(entry.csr(), self.cfg.clone())))
            }
            EngineKind::Native => match class.ladder() {
                Some(_) => {
                    let engine = LadderEngine::with_streams(
                        entry.prepared.clone(),
                        class,
                        &self.cfg,
                        |p| entry.values(p),
                    )?;
                    Ok(Box::new(engine))
                }
                None => Ok(Box::new(NativeEngine::with_values(
                    entry.prepared.clone(),
                    entry.values(self.cfg.precision),
                    self.cfg.clone(),
                ))),
            },
            EngineKind::Pjrt => self.build_prepared(entry.prepared.clone()),
        }
    }

    /// Stand up a [`Server`] with `workers` engines of this kind, taking
    /// the batching timeout and default top-N from the run configuration.
    pub fn serve(&self, graph: &Graph, workers: usize) -> Result<Server> {
        let engines = self.build_pool(graph, workers)?;
        let mut cfg = ServerConfig::from_run(&self.cfg);
        cfg.fault = self.fault.clone();
        cfg.backend = self.kind;
        Server::start(engines, cfg)
    }

    /// Stand up a multi-graph [`Server`]: `workers` threads resolving
    /// per-batch against `registry`, building engines of this kind on
    /// demand (see [`Server::start_registry`]).
    pub fn serve_registry(
        &self,
        registry: Arc<GraphRegistry>,
        workers: usize,
    ) -> Result<Server> {
        let mut cfg = ServerConfig::from_run(&self.cfg);
        cfg.fault = self.fault.clone();
        cfg.backend = self.kind;
        Server::start_registry(registry, self.clone(), workers, cfg)
    }

    /// Stand up a multi-graph [`Server`] with cost-model-driven
    /// heterogeneous dispatch (DESIGN.md §12): one worker group of
    /// `workers_per_backend` threads per *available* backend (this
    /// builder's kind first; backends whose probe build fails — PJRT
    /// without artifacts — are excluded), batches routed per `dispatch`
    /// (see [`Server::start_dispatch`]).
    pub fn serve_registry_dispatch(
        &self,
        registry: Arc<GraphRegistry>,
        workers_per_backend: usize,
        dispatch: &crate::config::DispatchConfig,
    ) -> Result<Server> {
        let mut cfg = ServerConfig::from_run(&self.cfg);
        cfg.fault = self.fault.clone();
        cfg.backend = self.kind;
        Server::start_dispatch(registry, self.clone(), workers_per_backend, dispatch, cfg)
    }

    fn spawn_pjrt(&self, prepared: Arc<PreparedGraph>) -> Result<Box<dyn PprEngine + Send>> {
        let dir = PathBuf::from(&self.cfg.artifacts_dir);
        let label = self
            .artifact_label
            .clone()
            .unwrap_or_else(|| self.cfg.precision.label().to_ascii_lowercase());
        let cfg = self.cfg.clone();
        let num_vertices = prepared.num_vertices;
        let engine = ThreadBoundEngine::spawn(move || {
            let rt = crate::runtime::Runtime::cpu()?;
            let inner = crate::runtime::PjrtPprEngine::load(&rt, &dir, &label, &prepared)
                .with_context(|| format!("load PJRT artifact {label}"))?;
            Ok(Box::new(PjrtEngineAdapter::new(inner, &cfg, num_vertices)) as Box<dyn PprEngine>)
        })?;
        Ok(Box::new(engine))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ScoreBlock;
    use crate::fixed::Precision;

    fn graph() -> Graph {
        crate::graph::generators::watts_strogatz(128, 6, 0.2, 5)
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [EngineKind::Native, EngineKind::Pjrt, EngineKind::CpuBaseline] {
            assert_eq!(EngineKind::parse(kind.label()), Some(kind), "{kind}");
        }
        assert_eq!(EngineKind::parse("CPU"), Some(EngineKind::CpuBaseline));
        assert_eq!(EngineKind::parse("fpga"), None);
    }

    #[test]
    fn kind_u8_codec_round_trips_and_zero_is_unset() {
        for kind in EngineKind::all() {
            assert_eq!(EngineKind::from_u8(kind.as_u8()), Some(kind));
            assert_ne!(kind.as_u8(), 0);
        }
        assert_eq!(EngineKind::from_u8(0), None);
        assert_eq!(EngineKind::from_u8(200), None);
    }

    #[test]
    fn backend_cell_shares_one_slot_across_clones() {
        let cell = BackendCell::new();
        let clone = cell.clone();
        assert_eq!(cell.get(), None);
        clone.set(EngineKind::CpuBaseline);
        assert_eq!(cell.get(), Some(EngineKind::CpuBaseline));
        // last write wins (degraded retry overwrites the failed stamp)
        cell.set(EngineKind::Native);
        assert_eq!(clone.get(), Some(EngineKind::Native));
    }

    #[test]
    fn with_kind_retargets_but_keeps_config() {
        let cfg = RunConfig { kappa: 3, iterations: 7, ..Default::default() };
        let b = EngineBuilder::native().config(cfg);
        let cpu = b.with_kind(EngineKind::CpuBaseline);
        assert_eq!(cpu.kind(), EngineKind::CpuBaseline);
        assert_eq!(cpu.run_config().kappa, 3);
        assert_eq!(cpu.run_config().iterations, 7);
        assert_eq!(b.kind(), EngineKind::Native, "original untouched");
    }

    #[test]
    fn builds_native_engine() {
        let cfg = RunConfig { precision: Precision::Fixed(24), kappa: 4, ..Default::default() };
        let mut e = EngineBuilder::native().config(cfg).build(&graph()).unwrap();
        assert_eq!(e.max_kappa(), 4);
        let mut block = ScoreBlock::new();
        e.run_batch(&[3], &mut block).unwrap();
        assert_eq!(block.top_n(0, 1)[0].vertex, 3);
    }

    #[test]
    fn builds_cpu_baseline_engine() {
        let cfg = RunConfig { kappa: 2, iterations: 15, ..Default::default() };
        let e = EngineBuilder::cpu_baseline().config(cfg).build(&graph()).unwrap();
        assert!(e.describe().contains("cpu-baseline"));
        assert_eq!(e.num_vertices(), 128);
    }

    #[test]
    fn pool_shares_preparation() {
        let cfg = RunConfig { kappa: 2, iterations: 5, ..Default::default() };
        let pool = EngineBuilder::native().config(cfg).build_pool(&graph(), 3).unwrap();
        assert_eq!(pool.len(), 3);
        assert!(pool.iter().all(|e| e.num_vertices() == 128));
    }

    #[test]
    fn shard_count_flows_from_config() {
        let cfg = RunConfig { kappa: 2, iterations: 5, num_shards: 3, ..Default::default() };
        let mut e = EngineBuilder::native().config(cfg).build(&graph()).unwrap();
        assert!(e.describe().contains("S=3"), "{}", e.describe());
        // sharded engine still serves correct rankings
        let mut block = ScoreBlock::new();
        e.run_batch(&[7], &mut block).unwrap();
        assert_eq!(block.top_n(0, 1)[0].vertex, 7);
    }

    #[test]
    fn invalid_config_rejected() {
        let cfg = RunConfig { kappa: 0, ..Default::default() };
        assert!(EngineBuilder::native().config(cfg).build(&graph()).is_err());
    }

    #[test]
    fn pjrt_without_artifacts_fails_cleanly() {
        let cfg = RunConfig {
            artifacts_dir: "definitely/not/a/dir".to_string(),
            ..Default::default()
        };
        // either the manifest is missing or (with the stubbed xla crate)
        // client creation fails — both must surface as a clean error
        assert!(EngineBuilder::pjrt().config(cfg).build(&graph()).is_err());
    }

    #[test]
    fn cpu_baseline_rejects_prepared_path() {
        let pg = Arc::new(crate::ppr::PreparedGraph::new(&graph(), 8));
        assert!(EngineBuilder::cpu_baseline().build_prepared(pg).is_err());
    }

    #[test]
    fn build_entry_covers_native_and_cpu_baseline() {
        let registry = GraphRegistry::new(2);
        registry.register_graph("g", graph()).unwrap();
        let cfg = RunConfig { kappa: 2, iterations: 5, num_shards: 1, ..Default::default() };
        let entry = registry.resolve("g", cfg.b, 1).unwrap();

        let mut native = EngineBuilder::native().config(cfg.clone()).build_entry(&entry).unwrap();
        assert_eq!(native.num_vertices(), 128);
        let mut block = ScoreBlock::new();
        native.run_batch(&[3], &mut block).unwrap();
        assert_eq!(block.top_n(0, 1)[0].vertex, 3);

        let cpu = EngineBuilder::cpu_baseline().config(cfg).build_entry(&entry).unwrap();
        assert!(cpu.describe().contains("cpu-baseline"));
        assert_eq!(cpu.num_vertices(), 128);
    }

    #[test]
    fn build_entry_class_builds_ladders_and_falls_back() {
        let registry = GraphRegistry::new(2);
        registry.register_graph("g", graph()).unwrap();
        let cfg = RunConfig { kappa: 2, num_shards: 1, ..Default::default() };
        let entry = registry.resolve("g", cfg.b, 1).unwrap();

        let b = EngineBuilder::native().config(cfg.clone());
        let mut ladder = b.build_entry_class(&entry, AccuracyClass::Balanced).unwrap();
        assert!(ladder.describe().contains("ladder"), "{}", ladder.describe());
        let mut block = ScoreBlock::new();
        ladder.run_batch(&[5], &mut block).unwrap();
        assert_eq!(block.top_n(0, 1)[0].vertex, 5);
        // the ladder's rung streams came from the entry's cache
        assert!(entry.resident_value_streams() >= 3, "one stream per rung cached");

        // Static falls back to the static engine
        let stat = b.build_entry_class(&entry, AccuracyClass::Static).unwrap();
        assert!(stat.describe().contains("native"), "{}", stat.describe());
        // non-native backends fall back too (CPU baseline is f32-only)
        let cpu = EngineBuilder::cpu_baseline()
            .config(cfg)
            .build_entry_class(&entry, AccuracyClass::Exact)
            .unwrap();
        assert!(cpu.describe().contains("cpu-baseline"), "{}", cpu.describe());
    }

    #[test]
    fn ladder_class_config_flows_through_build() {
        let cfg = RunConfig {
            kappa: 2,
            accuracy_class: AccuracyClass::Fast,
            ..Default::default()
        };
        let mut e = EngineBuilder::native().config(cfg).build(&graph()).unwrap();
        assert!(e.describe().contains("ladder[fast"), "{}", e.describe());
        let mut block = ScoreBlock::new();
        e.run_batch(&[7], &mut block).unwrap();
        assert_eq!(block.top_n(0, 1)[0].vertex, 7);
    }

    #[test]
    fn prep_shards_divides_among_workers() {
        let cfg = RunConfig { num_shards: 8, ..Default::default() };
        let b = EngineBuilder::native().config(cfg.clone());
        assert_eq!(b.prep_shards(1), 8);
        assert_eq!(b.prep_shards(4), 2);
        assert_eq!(b.prep_shards(16), 1, "never below one shard");
        assert_eq!(EngineBuilder::pjrt().config(cfg).prep_shards(1), 1, "pjrt reads one stream");
    }

    #[test]
    fn serve_registry_round_trips_a_query() {
        let registry = Arc::new(GraphRegistry::new(2));
        registry.register_graph("main", graph()).unwrap();
        let cfg = RunConfig {
            kappa: 2,
            iterations: 10,
            num_shards: 1,
            batch_timeout_ms: 2,
            ..Default::default()
        };
        let server =
            EngineBuilder::native().config(cfg).serve_registry(registry, 1).unwrap();
        let resp = server.query_graph("main", 11, 3).unwrap();
        assert_eq!(resp.ranking[0].vertex, 11);
        server.shutdown();
    }
}
