//! Dynamic batcher: accumulate requests until the accelerator's κ lanes
//! are full, or a timeout expires with at least one request pending — the
//! classic latency/throughput knob of serving systems, and the host-side
//! realization of the paper's "batch multiple user requests" design.
//!
//! The batcher is **graph- and class-keyed** (DESIGN.md §6/§7): each
//! registered graph is its own personalization space and each accuracy
//! class its own engine configuration, so a flush yields a [`GraphBatch`]
//! whose requests all target one `(graph, class)` pair — batches never
//! mix graphs and never mix classes. Keys with pending work are drained
//! round-robin: while one key's batch is being assembled it leaves the
//! rotation, so concurrent workers pick up *other* keys instead of
//! contending for the same queue.
//!
//! Flush deadlines are anchored at the **front request's arrival**, not
//! at the moment a worker claims the key: the batcher stamps every
//! request on `submit`, and `next_batch` computes the deadline from the
//! front stamp — so a request that aged in the queue while all workers
//! were busy flushes immediately instead of waiting a second full
//! timeout (worst-case queue wait ≤ one flush timeout plus batch
//! execution, pinned by regression tests).

use super::dispatch::BatchFeatures;
use super::request::PprRequest;
use crate::fixed::AccuracyClass;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One flushed batch: up to κ requests, all for the same graph and
/// accuracy class.
#[derive(Debug)]
pub struct GraphBatch {
    /// The graph every request in this batch targets.
    pub graph: Arc<str>,
    /// The accuracy class every request in this batch runs under.
    pub class: AccuracyClass,
    /// The requests (1..=κ of them).
    pub requests: Vec<PprRequest>,
}

impl GraphBatch {
    /// Lanes this batch occupies.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch carries no requests (never returned by
    /// [`DynamicBatcher::next_batch`]; provided for completeness).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// The largest `top_n` any request in this batch asks for — the K a
    /// top-K-native engine run needs to answer every request as a prefix
    /// of the ranked lanes (`None` for an empty batch).
    pub fn top_k_hint(&self) -> Option<usize> {
        self.requests.iter().map(|r| r.top_n).max()
    }
}

/// The batching key: one graph × one accuracy class.
type BatchKey = (Arc<str>, AccuracyClass);

/// A queued request plus the instant the batcher accepted it — the
/// anchor of its batch's flush deadline.
struct Queued {
    at: Instant,
    req: PprRequest,
}

/// Thread-safe graph/class-keyed batching queue.
pub struct DynamicBatcher {
    kappa: usize,
    timeout: Duration,
    inner: Mutex<Inner>,
    cv: Condvar,
}

struct Inner {
    /// Per-key FIFO queues (entries persist once a key is seen).
    queues: HashMap<BatchKey, VecDeque<Queued>>,
    /// Round-robin rotation of keys with pending requests. Invariant: a
    /// key is in the rotation iff its queue is non-empty **and** no
    /// worker is currently assembling its batch (the assembling worker
    /// pops the key and re-inserts it only if requests are left over).
    rotation: VecDeque<BatchKey>,
    /// Total queued requests across keys.
    depth: usize,
    closed: bool,
}

impl Inner {
    fn queue_len(&self, key: &BatchKey) -> usize {
        self.queues.get(key).map_or(0, |q| q.len())
    }
}

impl DynamicBatcher {
    /// Create a batcher for κ-lane batches with the given flush timeout.
    pub fn new(kappa: usize, timeout: Duration) -> Self {
        assert!(kappa >= 1);
        Self {
            kappa,
            timeout,
            inner: Mutex::new(Inner {
                queues: HashMap::new(),
                rotation: VecDeque::new(),
                depth: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request on its graph's queue. Returns `false` if the
    /// batcher is closed.
    ///
    /// Wake-up policy: a mid-fill request (the graph is pending or being
    /// assembled, and still short of κ) wakes **one** waiter —
    /// `notify_all` would stampede every idle worker through the mutex
    /// for a signal nobody must act on (the assembler re-checks its fill
    /// on timeout anyway, and an idle worker can do nothing with a
    /// claimed graph). Two transitions *must* reach a specific sleeper
    /// and therefore wake **all** waiters, because with per-graph claims
    /// a single wake-up landing on the wrong worker is simply swallowed:
    ///
    /// - a request that **activates** a graph (0→1, enters the rotation)
    ///   must reach an idle worker — an assembler that eats the wake-up
    ///   will not absorb another graph's request into its batch;
    /// - a request that **completes κ** must reach that graph's
    ///   assembler, or a ready full batch idles until the flush timeout.
    ///
    /// [`next_batch`]: Self::next_batch
    pub fn submit(&self, req: PprRequest) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        let key = (req.graph.clone(), req.class);
        let q = inner.queues.entry(key.clone()).or_default();
        let was_empty = q.is_empty();
        // stamp the arrival: the flush deadline of this request's batch
        // anchors here, not at whenever a worker gets around to claiming
        q.push_back(Queued { at: Instant::now(), req });
        // fires exactly once per κ-crossing (queues grow one request at a
        // time); a backlog left ≥ κ after a drain re-enters the rotation
        // and gets next_batch's hand-off notify_all instead
        let filled = q.len() == self.kappa;
        inner.depth += 1;
        // 0→1 means no worker owns this key right now (an assembling
        // worker would still hold ≥1 request in the queue), so it must
        // re-enter the rotation
        if was_empty && !inner.rotation.contains(&key) {
            inner.rotation.push_back(key);
            self.cv.notify_all();
        } else if filled {
            self.cv.notify_all();
        } else {
            self.cv.notify_one();
        }
        true
    }

    /// Blocking: wait for the next batch. Takes the front key of the
    /// round-robin rotation and returns up to κ of its requests — exactly
    /// κ when that key's queue is hot, fewer when the flush deadline
    /// expires first. Returns `None` when closed and drained.
    pub fn next_batch(&self) -> Option<GraphBatch> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // wait for any key with pending requests (or closure)
            while inner.rotation.is_empty() {
                if inner.closed {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
            }
            // claim the front key: out of the rotation while assembling,
            // so other workers drain other keys meanwhile
            let key = inner.rotation.pop_front().expect("rotation non-empty");
            // the flush deadline anchors at the FRONT request's arrival —
            // a request that already aged `timeout` in the queue (all
            // workers busy) flushes immediately instead of waiting a
            // second full timeout from the claim
            let deadline = inner
                .queues
                .get(&key)
                .and_then(|q| q.front())
                .map_or_else(Instant::now, |front| front.at + self.timeout);
            while inner.queue_len(&key) < self.kappa && !inner.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _res) = self.cv.wait_timeout(inner, deadline - now).unwrap();
                inner = guard;
            }
            let q = inner.queues.get_mut(&key).expect("claimed key has a queue");
            let take = q.len().min(self.kappa);
            let requests: Vec<PprRequest> = q.drain(..take).map(|queued| queued.req).collect();
            let leftover = !q.is_empty();
            inner.depth -= requests.len();
            if leftover {
                // rotate to the back: other keys get their turn first
                inner.rotation.push_back(key.clone());
            }
            // hand-off: if work remains (this key's leftovers or other
            // keys whose wake-ups all landed on this worker while it was
            // assembling), wake the waiters before going compute. Like the
            // rotation-entry wake in submit, this must reach an *idle*
            // worker, and a single wake-up can be swallowed by a worker
            // mid-assembly on another key — so notify_all.
            if !inner.rotation.is_empty() {
                self.cv.notify_all();
            }
            if requests.is_empty() {
                continue; // defensive: claimed keys always hold ≥1 request
            }
            let (graph, class) = key;
            return Some(GraphBatch { graph, class, requests });
        }
    }

    /// Close the batcher: pending requests still drain, new submissions
    /// are rejected, workers wake up.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Queue depth across all graphs (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().depth
    }

    /// Queue depth of one graph, summed over its classes (diagnostics).
    pub fn depth_of(&self, graph: &str) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .queues
            .iter()
            .filter(|((g, _), _)| g.as_ref() == graph)
            .map(|(_, q)| q.len())
            .sum()
    }

    /// The κ this batcher fills toward.
    pub fn kappa(&self) -> usize {
        self.kappa
    }
}

/// A batch the dispatcher has priced and routed: the flushed
/// [`GraphBatch`] plus the features it was scored on and the predicted
/// solve time carried on its lane's pending ledger.
#[derive(Debug)]
pub struct RoutedBatch {
    /// The flushed batch.
    pub batch: GraphBatch,
    /// The workload shape the cost models scored.
    pub features: BatchFeatures,
    /// Predicted solve nanoseconds on the lane it was routed to — added
    /// to that lane's pending ledger on push, removed on pop/steal.
    pub predicted_solve_nanos: u64,
}

/// Steal-safe per-backend batch queues with per-lane pending-time
/// ledgers — the hand-off between the dispatch pump and the per-backend
/// worker groups (DESIGN.md §12).
///
/// A worker pops the **front** of its own lane; an idle worker may steal
/// the **back** of another lane (the batch that would otherwise wait
/// longest) when the caller-supplied predicate — the dispatcher's
/// [`steal_allowed`](super::dispatch::Dispatcher::steal_allowed) — says
/// the thief finishes it sooner. Pop and steal both run under one mutex,
/// so a batch is claimed by exactly one worker: never duplicated, never
/// dropped (property-tested below). After [`LaneSet::close`] the
/// predicate is bypassed so stragglers drain onto whichever worker asks
/// first.
pub struct LaneSet {
    inner: Mutex<LaneInner>,
    cv: Condvar,
}

struct LaneInner {
    lanes: Vec<VecDeque<RoutedBatch>>,
    pending_nanos: Vec<u64>,
    closed: bool,
}

/// How long an idle worker sleeps between steal re-evaluations: steal
/// eligibility drifts as other lanes drain, so waiters re-check on a
/// short timer as well as on push/close wake-ups.
const STEAL_RECHECK: Duration = Duration::from_millis(10);

impl LaneSet {
    /// New set with `num_lanes` empty queues.
    pub fn new(num_lanes: usize) -> Self {
        assert!(num_lanes >= 1);
        Self {
            inner: Mutex::new(LaneInner {
                lanes: (0..num_lanes).map(|_| VecDeque::new()).collect(),
                pending_nanos: vec![0; num_lanes],
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Number of lanes.
    pub fn num_lanes(&self) -> usize {
        self.inner.lock().unwrap().lanes.len()
    }

    /// Enqueue a routed batch on its lane and grow the lane's pending
    /// ledger by the predicted solve time. Returns `false` when closed.
    pub fn push(&self, lane: usize, rb: RoutedBatch) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        inner.pending_nanos[lane] =
            inner.pending_nanos[lane].saturating_add(rb.predicted_solve_nanos);
        inner.lanes[lane].push_back(rb);
        self.cv.notify_all();
        true
    }

    /// Blocking: pop the front of `lane`, or — when it is empty — steal
    /// the back of another lane for which `can_steal(owner,
    /// owner_pending_nanos, batch)` approves (bypassed once closed, so
    /// the set always drains). Returns the batch and `Some(owner)` when
    /// it was stolen, `None` when the set is closed and fully drained.
    pub fn pop_or_steal(
        &self,
        lane: usize,
        can_steal: &dyn Fn(usize, u64, &RoutedBatch) -> bool,
    ) -> Option<(RoutedBatch, Option<usize>)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(rb) = inner.lanes[lane].pop_front() {
                inner.pending_nanos[lane] =
                    inner.pending_nanos[lane].saturating_sub(rb.predicted_solve_nanos);
                return Some((rb, None));
            }
            let closed = inner.closed;
            let n = inner.lanes.len();
            for owner in (0..n).filter(|&o| o != lane) {
                let approved = match inner.lanes[owner].back() {
                    Some(rb) => closed || can_steal(owner, inner.pending_nanos[owner], rb),
                    None => false,
                };
                if approved {
                    let rb = inner.lanes[owner].pop_back().expect("checked non-empty");
                    inner.pending_nanos[owner] =
                        inner.pending_nanos[owner].saturating_sub(rb.predicted_solve_nanos);
                    return Some((rb, Some(owner)));
                }
            }
            if closed && inner.lanes.iter().all(|q| q.is_empty()) {
                return None;
            }
            let (guard, _res) = self.cv.wait_timeout(inner, STEAL_RECHECK).unwrap();
            inner = guard;
        }
    }

    /// Each lane's pending ledger (predicted solve nanoseconds queued).
    pub fn pending_nanos(&self) -> Vec<u64> {
        self.inner.lock().unwrap().pending_nanos.clone()
    }

    /// Each lane's queue depth in batches.
    pub fn depths(&self) -> Vec<usize> {
        self.inner.lock().unwrap().lanes.iter().map(|q| q.len()).collect()
    }

    /// Close the set: queued batches still drain (steal predicate
    /// bypassed), new pushes are rejected, idle workers wake up.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> PprRequest {
        PprRequest::new(id, id as u32, 10)
    }

    fn req_on(id: u64, graph: &Arc<str>) -> PprRequest {
        PprRequest::new(id, id as u32, 10).with_graph(graph.clone())
    }

    #[test]
    fn full_batch_returned_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            assert!(b.submit(req(i)));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.requests[0].id, 0);
        assert_eq!(batch.graph.as_ref(), super::super::request::DEFAULT_GRAPH);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let b = DynamicBatcher::new(8, Duration::from_millis(20));
        b.submit(req(1));
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn close_wakes_waiters() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_secs(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(!b.submit(req(9)), "closed batcher rejects submissions");
    }

    #[test]
    fn close_drains_pending() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.submit(req(1));
        b.submit(req(2));
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn single_submit_wakes_exactly_one_batch() {
        // regression for the partial-batch path: one request against a
        // κ=8 batcher must flush alone on timeout, not wait for κ
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        b.submit(req(42));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.requests[0].id, 42);
    }

    #[test]
    fn notify_one_loses_no_requests_across_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(3)));
        let served = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let b = b.clone();
                let served = served.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        served.fetch_add(batch.len(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..50 {
            assert!(b.submit(req(i)));
            if i % 9 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        b.close(); // pending requests drain before workers exit
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(served.load(Ordering::SeqCst), 50, "every request served exactly once");
    }

    #[test]
    fn flush_deadline_anchored_to_enqueue_not_claim() {
        // regression: the deadline used to be armed at claim time, so a
        // request that aged while every worker was busy waited up to TWO
        // flush timeouts. With arrival anchoring, a request older than
        // the timeout flushes the moment a worker claims its key.
        let b = DynamicBatcher::new(8, Duration::from_millis(100));
        b.submit(req(1));
        std::thread::sleep(Duration::from_millis(130)); // workers "busy"
        let claim = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            claim.elapsed() < Duration::from_millis(60),
            "aged request must flush immediately, waited {:?}",
            claim.elapsed()
        );
    }

    #[test]
    fn worst_case_queue_wait_is_one_flush_timeout() {
        // end-to-end: submit → worker claims after Δ < timeout → flush at
        // enqueue + timeout, NOT at claim + timeout
        let timeout = Duration::from_millis(200);
        let b = DynamicBatcher::new(8, timeout);
        let submitted = Instant::now();
        b.submit(req(1));
        std::thread::sleep(Duration::from_millis(150));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = submitted.elapsed();
        // claim-anchoring would wait ≈ 350 ms; arrival-anchoring ≈ 200 ms
        assert!(
            waited < Duration::from_millis(300),
            "queue wait {waited:?} exceeds one flush timeout + slack"
        );
        assert!(waited >= timeout, "partial batch still waits out the flush window");
    }

    #[test]
    fn batches_never_mix_accuracy_classes() {
        use crate::fixed::AccuracyClass;
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        for i in 0..4 {
            b.submit(req(i).with_class(AccuracyClass::Fast));
            b.submit(req(100 + i).with_class(AccuracyClass::Exact));
        }
        assert_eq!(b.depth(), 8);
        assert_eq!(b.depth_of(super::super::request::DEFAULT_GRAPH), 8);
        for _ in 0..2 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 4, "each class flushes its own full κ batch");
            assert!(
                batch.requests.iter().all(|r| r.class == batch.class),
                "one ladder per batch"
            );
            assert_eq!(batch.graph.as_ref(), super::super::request::DEFAULT_GRAPH);
        }
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn top_k_hint_is_the_batch_max() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.submit(PprRequest::new(1, 1, 5));
        b.submit(PprRequest::new(2, 2, 100));
        b.submit(PprRequest::new(3, 3, 10));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.top_k_hint(), Some(100));
        let empty = GraphBatch {
            graph: Arc::from("x"),
            class: AccuracyClass::Static,
            requests: Vec::new(),
        };
        assert_eq!(empty.top_k_hint(), None);
    }

    #[test]
    fn default_class_is_static_in_batches() {
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        b.submit(req(1));
        b.submit(req(2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.class, crate::fixed::AccuracyClass::Static);
    }

    #[test]
    fn oversubmission_splits_batches() {
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn batches_never_mix_graphs() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        // interleave submissions across two graphs
        for i in 0..4 {
            b.submit(req_on(i, &a));
            b.submit(req_on(100 + i, &z));
        }
        assert_eq!(b.depth(), 8);
        assert_eq!(b.depth_of("a"), 4);
        assert_eq!(b.depth_of("z"), 4);
        for _ in 0..2 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 4, "each graph flushes a full κ batch");
            assert!(
                batch.requests.iter().all(|r| r.graph == batch.graph),
                "one personalization space per batch"
            );
        }
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn round_robin_across_graphs() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        // graph a has two batches' worth, z has one: the rotation must
        // interleave z between a's batches rather than starving it
        for i in 0..4 {
            b.submit(req_on(i, &a));
        }
        b.submit(req_on(50, &z));
        b.submit(req_on(51, &z));
        let order: Vec<String> =
            (0..3).map(|_| b.next_batch().unwrap().graph.as_ref().to_string()).collect();
        assert_eq!(order, vec!["a", "z", "a"], "leftover graphs rotate to the back");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn partial_flush_per_graph_on_timeout() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(8, Duration::from_millis(8));
        b.submit(req_on(1, &a));
        b.submit(req_on(2, &z));
        b.submit(req_on(3, &a));
        // neither graph fills κ=8: both flush as partial single-graph
        // batches once the timeout expires
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        let mut sizes = vec![(first.graph, first.len()), (second.graph, second.len())];
        sizes.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(sizes[0].1 + sizes[1].1, 3);
        assert_eq!(sizes[0].0.as_ref(), "a");
        assert_eq!(sizes[0].1, 2);
        assert_eq!(sizes[1].0.as_ref(), "z");
        assert_eq!(sizes[1].1, 1);
    }

    #[test]
    fn multi_graph_load_drains_completely() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let graphs: Vec<Arc<str>> = ["g0", "g1", "g2"].iter().map(|&g| Arc::from(g)).collect();
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(2)));
        let served = Arc::new(AtomicUsize::new(0));
        let mixed = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let b = b.clone();
                let served = served.clone();
                let mixed = mixed.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        if batch.requests.iter().any(|r| r.graph != batch.graph) {
                            mixed.fetch_add(1, Ordering::SeqCst);
                        }
                        served.fetch_add(batch.len(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..90u64 {
            assert!(b.submit(req_on(i, &graphs[(i % 3) as usize])));
            if i % 13 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        b.close();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(served.load(Ordering::SeqCst), 90, "every request served exactly once");
        assert_eq!(mixed.load(Ordering::SeqCst), 0, "no batch ever mixes graphs");
    }

    fn routed(id: u64, nanos: u64) -> RoutedBatch {
        RoutedBatch {
            batch: GraphBatch {
                graph: Arc::from("g"),
                class: AccuracyClass::Static,
                requests: vec![req(id)],
            },
            features: BatchFeatures {
                num_vertices: 100,
                num_edges: 400,
                num_packets: 50,
                lanes: 1,
                iterations: 10,
                class: AccuracyClass::Static,
                shards: 1,
            },
            predicted_solve_nanos: nanos,
        }
    }

    #[test]
    fn lane_set_tracks_pending_ledger_and_gates_steals() {
        let set = LaneSet::new(2);
        assert!(set.push(0, routed(1, 500)));
        assert!(set.push(0, routed(2, 700)));
        assert_eq!(set.pending_nanos(), vec![1200, 0]);
        assert_eq!(set.depths(), vec![2, 0]);
        // own-lane pop comes from the FRONT and shrinks the ledger
        let (rb, stolen_from) = set.pop_or_steal(0, &|_, _, _| false).unwrap();
        assert_eq!(rb.batch.requests[0].id, 1);
        assert_eq!(stolen_from, None);
        assert_eq!(set.pending_nanos(), vec![700, 0]);
        // a steal takes the BACK of the owner's lane and reports the owner
        let (rb, stolen_from) = set.pop_or_steal(1, &|owner, pending, _| {
            assert_eq!(owner, 0);
            assert_eq!(pending, 700);
            true
        })
        .unwrap();
        assert_eq!(rb.batch.requests[0].id, 2);
        assert_eq!(stolen_from, Some(0));
        assert_eq!(set.pending_nanos(), vec![0, 0]);
        // closed + drained → None; closed set rejects pushes
        set.close();
        assert!(set.pop_or_steal(0, &|_, _, _| false).is_none());
        assert!(!set.push(0, routed(3, 1)));
    }

    #[test]
    fn lane_set_close_bypasses_steal_predicate_to_drain() {
        let set = LaneSet::new(2);
        set.push(0, routed(9, 100));
        set.close();
        // the predicate always refuses, but a closed set must still drain
        let (rb, stolen_from) = set.pop_or_steal(1, &|_, _, _| false).unwrap();
        assert_eq!(rb.batch.requests[0].id, 9);
        assert_eq!(stolen_from, Some(0));
        assert!(set.pop_or_steal(1, &|_, _, _| false).is_none());
    }

    #[test]
    fn lane_set_concurrent_flush_and_steal_never_duplicates_never_drops() {
        use std::collections::HashSet;
        use std::sync::atomic::{AtomicU64, Ordering};
        const BATCHES: u64 = 400;
        let set = Arc::new(LaneSet::new(2));
        let stolen = Arc::new(AtomicU64::new(0));
        // two workers per lane; lane-1 workers steal greedily, so lane-0
        // pops race lane-1 back-steals on the same queue throughout
        let workers: Vec<_> = (0..4)
            .map(|w| {
                let set = set.clone();
                let stolen = stolen.clone();
                std::thread::spawn(move || {
                    let lane = w % 2;
                    let mut seen = Vec::new();
                    while let Some((rb, from)) = set.pop_or_steal(lane, &|_, _, _| true) {
                        if from.is_some() {
                            stolen.fetch_add(1, Ordering::Relaxed);
                        }
                        seen.push(rb.batch.requests[0].id);
                    }
                    seen
                })
            })
            .collect();
        // the producer routes everything to lane 0: lane 1 can only eat
        // by stealing
        for id in 0..BATCHES {
            assert!(set.push(0, routed(id, 1_000)));
            if id % 37 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        set.close();
        let mut all: Vec<u64> = Vec::new();
        for w in workers {
            all.extend(w.join().unwrap());
        }
        assert_eq!(all.len() as u64, BATCHES, "no batch dropped");
        let unique: HashSet<u64> = all.iter().copied().collect();
        assert_eq!(unique.len() as u64, BATCHES, "no batch served twice");
        assert!(stolen.load(Ordering::Relaxed) > 0, "lane 1 exercised the steal path");
        assert_eq!(set.pending_nanos(), vec![0, 0], "ledgers return to zero");
    }
}
