//! Dynamic batcher: accumulate requests until the accelerator's κ lanes
//! are full, or a timeout expires with at least one request pending — the
//! classic latency/throughput knob of serving systems, and the host-side
//! realization of the paper's "batch multiple user requests" design.

use super::request::PprRequest;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Thread-safe batching queue.
pub struct DynamicBatcher {
    kappa: usize,
    timeout: Duration,
    inner: Mutex<Inner>,
    cv: Condvar,
}

struct Inner {
    queue: VecDeque<PprRequest>,
    closed: bool,
}

impl DynamicBatcher {
    /// Create a batcher for κ-lane batches with the given flush timeout.
    pub fn new(kappa: usize, timeout: Duration) -> Self {
        assert!(kappa >= 1);
        Self {
            kappa,
            timeout,
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. Returns `false` if the batcher is closed.
    ///
    /// Wakes exactly **one** waiter: a single request needs a single
    /// worker, and `notify_all` here stampedes every idle worker through
    /// the mutex just to find an empty queue. A wake-up consumed by a
    /// worker already assembling a batch is not lost: [`next_batch`]
    /// hands leftover work to another waiter when it drains (see the
    /// hand-off notify there). `notify_all` is reserved for
    /// [`close`](Self::close), where every waiter really must observe
    /// the state change.
    ///
    /// [`next_batch`]: Self::next_batch
    pub fn submit(&self, req: PprRequest) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        inner.queue.push_back(req);
        self.cv.notify_one();
        true
    }

    /// Blocking: wait for the next batch. Returns up to κ requests —
    /// exactly κ when the queue is hot, fewer when the flush timeout
    /// expires first. Returns `None` when closed and drained.
    pub fn next_batch(&self) -> Option<Vec<PprRequest>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // wait for the first request (or closure)
            while inner.queue.is_empty() {
                if inner.closed {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
            }
            // first request in hand: wait up to `timeout` for a full batch
            let deadline = Instant::now() + self.timeout;
            while inner.queue.len() < self.kappa && !inner.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _res) = self.cv.wait_timeout(inner, deadline - now).unwrap();
                inner = guard;
            }
            if inner.queue.is_empty() {
                continue; // raced with another worker
            }
            let take = inner.queue.len().min(self.kappa);
            let batch = inner.queue.drain(..take).collect();
            // hand-off: if submissions outran this batch (their wake-ups
            // may all have landed on this worker while it was assembling),
            // wake one more worker for the leftovers before going compute
            if !inner.queue.is_empty() {
                self.cv.notify_one();
            }
            return Some(batch);
        }
    }

    /// Close the batcher: pending requests still drain, new submissions
    /// are rejected, workers wake up.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Queue depth (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// The κ this batcher fills toward.
    pub fn kappa(&self) -> usize {
        self.kappa
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> PprRequest {
        PprRequest::new(id, id as u32, 10)
    }

    #[test]
    fn full_batch_returned_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            assert!(b.submit(req(i)));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let b = DynamicBatcher::new(8, Duration::from_millis(20));
        b.submit(req(1));
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn close_wakes_waiters() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_secs(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(!b.submit(req(9)), "closed batcher rejects submissions");
    }

    #[test]
    fn close_drains_pending() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.submit(req(1));
        b.submit(req(2));
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn single_submit_wakes_exactly_one_batch() {
        // regression for the partial-batch path: one request against a
        // κ=8 batcher must flush alone on timeout, not wait for κ
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        b.submit(req(42));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 42);
    }

    #[test]
    fn notify_one_loses_no_requests_across_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(3)));
        let served = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let b = b.clone();
                let served = served.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        served.fetch_add(batch.len(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..50 {
            assert!(b.submit(req(i)));
            if i % 9 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        b.close(); // pending requests drain before workers exit
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(served.load(Ordering::SeqCst), 50, "every request served exactly once");
    }

    #[test]
    fn oversubmission_splits_batches() {
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert_eq!(b.depth(), 0);
    }
}
