//! Dynamic batcher: accumulate requests until the accelerator's κ lanes
//! are full, or a timeout expires with at least one request pending — the
//! classic latency/throughput knob of serving systems, and the host-side
//! realization of the paper's "batch multiple user requests" design.
//!
//! The batcher is **graph-keyed** (DESIGN.md §6): each registered graph
//! is its own personalization space, so a flush yields a [`GraphBatch`]
//! whose requests all target one graph — batches never mix graphs. Graphs
//! with pending work are drained round-robin: while one graph's batch is
//! being assembled it leaves the rotation, so concurrent workers pick up
//! *other* graphs instead of contending for the same queue.

use super::request::PprRequest;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One flushed batch: up to κ requests, all for the same graph.
#[derive(Debug)]
pub struct GraphBatch {
    /// The graph every request in this batch targets.
    pub graph: Arc<str>,
    /// The requests (1..=κ of them).
    pub requests: Vec<PprRequest>,
}

impl GraphBatch {
    /// Lanes this batch occupies.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// True when the batch carries no requests (never returned by
    /// [`DynamicBatcher::next_batch`]; provided for completeness).
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Thread-safe graph-keyed batching queue.
pub struct DynamicBatcher {
    kappa: usize,
    timeout: Duration,
    inner: Mutex<Inner>,
    cv: Condvar,
}

struct Inner {
    /// Per-graph FIFO queues (entries persist once a graph is seen).
    queues: HashMap<Arc<str>, VecDeque<PprRequest>>,
    /// Round-robin rotation of graphs with pending requests. Invariant: a
    /// graph is in the rotation iff its queue is non-empty **and** no
    /// worker is currently assembling its batch (the assembling worker
    /// pops the graph and re-inserts it only if requests are left over).
    rotation: VecDeque<Arc<str>>,
    /// Total queued requests across graphs.
    depth: usize,
    closed: bool,
}

impl Inner {
    fn queue_len(&self, graph: &Arc<str>) -> usize {
        self.queues.get(graph).map_or(0, |q| q.len())
    }
}

impl DynamicBatcher {
    /// Create a batcher for κ-lane batches with the given flush timeout.
    pub fn new(kappa: usize, timeout: Duration) -> Self {
        assert!(kappa >= 1);
        Self {
            kappa,
            timeout,
            inner: Mutex::new(Inner {
                queues: HashMap::new(),
                rotation: VecDeque::new(),
                depth: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request on its graph's queue. Returns `false` if the
    /// batcher is closed.
    ///
    /// Wake-up policy: a mid-fill request (the graph is pending or being
    /// assembled, and still short of κ) wakes **one** waiter —
    /// `notify_all` would stampede every idle worker through the mutex
    /// for a signal nobody must act on (the assembler re-checks its fill
    /// on timeout anyway, and an idle worker can do nothing with a
    /// claimed graph). Two transitions *must* reach a specific sleeper
    /// and therefore wake **all** waiters, because with per-graph claims
    /// a single wake-up landing on the wrong worker is simply swallowed:
    ///
    /// - a request that **activates** a graph (0→1, enters the rotation)
    ///   must reach an idle worker — an assembler that eats the wake-up
    ///   will not absorb another graph's request into its batch;
    /// - a request that **completes κ** must reach that graph's
    ///   assembler, or a ready full batch idles until the flush timeout.
    ///
    /// [`next_batch`]: Self::next_batch
    pub fn submit(&self, req: PprRequest) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return false;
        }
        let graph = req.graph.clone();
        let q = inner.queues.entry(graph.clone()).or_default();
        let was_empty = q.is_empty();
        q.push_back(req);
        // fires exactly once per κ-crossing (queues grow one request at a
        // time); a backlog left ≥ κ after a drain re-enters the rotation
        // and gets next_batch's hand-off notify_all instead
        let filled = q.len() == self.kappa;
        inner.depth += 1;
        // 0→1 means no worker owns this graph right now (an assembling
        // worker would still hold ≥1 request in the queue), so it must
        // re-enter the rotation
        if was_empty && !inner.rotation.contains(&graph) {
            inner.rotation.push_back(graph);
            self.cv.notify_all();
        } else if filled {
            self.cv.notify_all();
        } else {
            self.cv.notify_one();
        }
        true
    }

    /// Blocking: wait for the next batch. Takes the front graph of the
    /// round-robin rotation and returns up to κ of its requests — exactly
    /// κ when that graph's queue is hot, fewer when the flush timeout
    /// expires first. Returns `None` when closed and drained.
    pub fn next_batch(&self) -> Option<GraphBatch> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // wait for any graph with pending requests (or closure)
            while inner.rotation.is_empty() {
                if inner.closed {
                    return None;
                }
                inner = self.cv.wait(inner).unwrap();
            }
            // claim the front graph: out of the rotation while assembling,
            // so other workers drain other graphs meanwhile
            let graph = inner.rotation.pop_front().expect("rotation non-empty");
            // first request in hand: wait up to `timeout` for a full batch
            let deadline = Instant::now() + self.timeout;
            while inner.queue_len(&graph) < self.kappa && !inner.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _res) = self.cv.wait_timeout(inner, deadline - now).unwrap();
                inner = guard;
            }
            let q = inner.queues.get_mut(&graph).expect("claimed graph has a queue");
            let take = q.len().min(self.kappa);
            let requests: Vec<PprRequest> = q.drain(..take).collect();
            let leftover = !q.is_empty();
            inner.depth -= requests.len();
            if leftover {
                // rotate to the back: other graphs get their turn first
                inner.rotation.push_back(graph.clone());
            }
            // hand-off: if work remains (this graph's leftovers or other
            // graphs whose wake-ups all landed on this worker while it was
            // assembling), wake the waiters before going compute. Like the
            // rotation-entry wake in submit, this must reach an *idle*
            // worker, and a single wake-up can be swallowed by a worker
            // mid-assembly on another graph — so notify_all.
            if !inner.rotation.is_empty() {
                self.cv.notify_all();
            }
            if requests.is_empty() {
                continue; // defensive: claimed graphs always hold ≥1 request
            }
            return Some(GraphBatch { graph, requests });
        }
    }

    /// Close the batcher: pending requests still drain, new submissions
    /// are rejected, workers wake up.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Queue depth across all graphs (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().depth
    }

    /// Queue depth of one graph (diagnostics).
    pub fn depth_of(&self, graph: &str) -> usize {
        self.inner.lock().unwrap().queues.get(graph).map_or(0, |q| q.len())
    }

    /// The κ this batcher fills toward.
    pub fn kappa(&self) -> usize {
        self.kappa
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> PprRequest {
        PprRequest::new(id, id as u32, 10)
    }

    fn req_on(id: u64, graph: &Arc<str>) -> PprRequest {
        PprRequest::new(id, id as u32, 10).with_graph(graph.clone())
    }

    #[test]
    fn full_batch_returned_immediately() {
        let b = DynamicBatcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            assert!(b.submit(req(i)));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.requests[0].id, 0);
        assert_eq!(batch.graph.as_ref(), super::super::request::DEFAULT_GRAPH);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let b = DynamicBatcher::new(8, Duration::from_millis(20));
        b.submit(req(1));
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn close_wakes_waiters() {
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_secs(10)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
        assert!(!b.submit(req(9)), "closed batcher rejects submissions");
    }

    #[test]
    fn close_drains_pending() {
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        b.submit(req(1));
        b.submit(req(2));
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn single_submit_wakes_exactly_one_batch() {
        // regression for the partial-batch path: one request against a
        // κ=8 batcher must flush alone on timeout, not wait for κ
        let b = DynamicBatcher::new(8, Duration::from_millis(10));
        b.submit(req(42));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch.requests[0].id, 42);
    }

    #[test]
    fn notify_one_loses_no_requests_across_workers() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(3)));
        let served = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let b = b.clone();
                let served = served.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        served.fetch_add(batch.len(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..50 {
            assert!(b.submit(req(i)));
            if i % 9 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        b.close(); // pending requests drain before workers exit
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(served.load(Ordering::SeqCst), 50, "every request served exactly once");
    }

    #[test]
    fn oversubmission_splits_batches() {
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        for i in 0..5 {
            b.submit(req(i));
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn batches_never_mix_graphs() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(4, Duration::from_millis(5));
        // interleave submissions across two graphs
        for i in 0..4 {
            b.submit(req_on(i, &a));
            b.submit(req_on(100 + i, &z));
        }
        assert_eq!(b.depth(), 8);
        assert_eq!(b.depth_of("a"), 4);
        assert_eq!(b.depth_of("z"), 4);
        for _ in 0..2 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 4, "each graph flushes a full κ batch");
            assert!(
                batch.requests.iter().all(|r| r.graph == batch.graph),
                "one personalization space per batch"
            );
        }
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn round_robin_across_graphs() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(2, Duration::from_millis(5));
        // graph a has two batches' worth, z has one: the rotation must
        // interleave z between a's batches rather than starving it
        for i in 0..4 {
            b.submit(req_on(i, &a));
        }
        b.submit(req_on(50, &z));
        b.submit(req_on(51, &z));
        let order: Vec<String> =
            (0..3).map(|_| b.next_batch().unwrap().graph.as_ref().to_string()).collect();
        assert_eq!(order, vec!["a", "z", "a"], "leftover graphs rotate to the back");
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn partial_flush_per_graph_on_timeout() {
        let a: Arc<str> = Arc::from("a");
        let z: Arc<str> = Arc::from("z");
        let b = DynamicBatcher::new(8, Duration::from_millis(8));
        b.submit(req_on(1, &a));
        b.submit(req_on(2, &z));
        b.submit(req_on(3, &a));
        // neither graph fills κ=8: both flush as partial single-graph
        // batches once the timeout expires
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        let mut sizes = vec![(first.graph, first.len()), (second.graph, second.len())];
        sizes.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(sizes[0].1 + sizes[1].1, 3);
        assert_eq!(sizes[0].0.as_ref(), "a");
        assert_eq!(sizes[0].1, 2);
        assert_eq!(sizes[1].0.as_ref(), "z");
        assert_eq!(sizes[1].1, 1);
    }

    #[test]
    fn multi_graph_load_drains_completely() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let graphs: Vec<Arc<str>> = ["g0", "g1", "g2"].iter().map(|&g| Arc::from(g)).collect();
        let b = Arc::new(DynamicBatcher::new(4, Duration::from_millis(2)));
        let served = Arc::new(AtomicUsize::new(0));
        let mixed = Arc::new(AtomicUsize::new(0));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let b = b.clone();
                let served = served.clone();
                let mixed = mixed.clone();
                std::thread::spawn(move || {
                    while let Some(batch) = b.next_batch() {
                        if batch.requests.iter().any(|r| r.graph != batch.graph) {
                            mixed.fetch_add(1, Ordering::SeqCst);
                        }
                        served.fetch_add(batch.len(), Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for i in 0..90u64 {
            assert!(b.submit(req_on(i, &graphs[(i % 3) as usize])));
            if i % 13 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        b.close();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(served.load(Ordering::SeqCst), 90, "every request served exactly once");
        assert_eq!(mixed.load(Ordering::SeqCst), 0, "no batch ever mixes graphs");
    }
}
