//! L3 coordinator — the serving layer the paper's use-case implies
//! (§1: "find recommended posts in a social network while users interact
//! with it, or recommended items for a given query on an e-commerce
//! platform"; §3: "we compute κ personalization vertices in parallel, to
//! batch multiple user requests").
//!
//! The accelerator surface is one coherent layer (DESIGN.md §3):
//!
//! - [`engine`] — the single [`PprEngine`] trait every backend implements
//!   (native bit-accurate, PJRT artifacts, CPU baseline), with
//!   variable-lane batches so timeout-flushed partial batches run as-is;
//! - [`score_block`] — [`ScoreBlock`], the reusable flat output buffer
//!   with zero-copy lane views and in-place top-N extraction;
//! - [`builder`] — [`EngineBuilder`], the one factory (`EngineKind` ×
//!   `RunConfig`) that the CLI, bench harness, examples and tests all
//!   construct engines through;
//! - [`registry`] — [`GraphRegistry`]: named graphs with lazily-prepared
//!   `Arc`-shared entries (LRU-bounded residency) and epoch-based
//!   hot-swap [`GraphRegistry::reload`] — the multi-graph serving
//!   substrate (DESIGN.md §6);
//! - [`request`] — typed queries/responses with latency accounting,
//!   per-graph routing and optional per-request deadlines;
//! - [`batcher`] — the graph-keyed dynamic batcher: fill the
//!   accelerator's κ lanes or flush on timeout, per graph, round-robin
//!   across graphs — one personalization space per batch;
//! - [`dispatch`] — cost-model-driven heterogeneous routing: a
//!   [`Dispatcher`] scores each flushed batch on every candidate backend
//!   (FPGA cycle model for native, measured-throughput EWMA for the CPU
//!   paths) and routes it to the argmin predicted completion time, with
//!   work-stealing between per-backend worker groups (DESIGN.md §12);
//! - [`server`] — worker threads (single-graph engine ownership or
//!   per-batch registry resolution with an engine cache), the
//!   non-blocking [`Ticket`] submission API with [`Server::submit_to`]
//!   routing, per-graph statistics, graceful shutdown;
//! - [`stats`] — latency percentiles and throughput counters (kept both
//!   in aggregate and per graph).
//!
//! The vendored crate set has no tokio; the coordinator is built on
//! `std::thread` + `mpsc` + `Condvar`, which is entirely adequate for a
//! compute-bound accelerator front-end (one in-flight batch per engine).

pub mod batcher;
pub mod builder;
pub mod dispatch;
pub mod engine;
pub mod registry;
pub mod request;
pub mod score_block;
pub mod server;
pub mod stats;

pub use batcher::{DynamicBatcher, GraphBatch, LaneSet, RoutedBatch};
pub use builder::{BackendCell, EngineBuilder, EngineKind};
pub use dispatch::{
    BackendLane, BatchFeatures, CostModel, DispatchPolicy, DispatchStats, Dispatcher,
    EwmaCostModel, PipelineCostModel,
};
pub use engine::{
    CpuBaselineEngine, LadderEngine, NativeEngine, PjrtEngineAdapter, PprEngine,
    ThreadBoundEngine,
};
pub use registry::{
    GraphEntry, GraphRegistry, GraphSource, RegisterError, DEFAULT_REGISTRY_CAPACITY,
    DISK_CAPACITY_FACTOR,
};
pub use request::{
    default_graph_key, validate_query, PprRequest, PprResponse, QueryError, RankedVertex,
    ServeError, DEFAULT_GRAPH,
};
pub use score_block::ScoreBlock;
pub use server::{Server, ServerConfig, Ticket, WorkerHealth};
pub use stats::ServerStats;
