//! L3 coordinator — the serving layer the paper's use-case implies
//! (§1: "find recommended posts in a social network while users interact
//! with it, or recommended items for a given query on an e-commerce
//! platform"; §3: "we compute κ personalization vertices in parallel, to
//! batch multiple user requests").
//!
//! - [`request`] — typed queries/responses with latency accounting.
//! - [`batcher`] — the dynamic batcher: fill the accelerator's κ lanes or
//!   flush on timeout (the host-side half of the paper's batching design).
//! - [`engine`] — the accelerator abstraction: the bit-accurate native
//!   engine (paper-scale experiments) and the PJRT engine running the AOT
//!   artifacts (the three-layer serving path).
//! - [`server`] — worker threads, submission API, graceful shutdown.
//! - [`stats`] — latency percentiles and throughput counters.
//!
//! The vendored crate set has no tokio; the coordinator is built on
//! `std::thread` + `mpsc` + `Condvar`, which is entirely adequate for a
//! compute-bound accelerator front-end (one in-flight batch per engine).

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod stats;

pub use batcher::DynamicBatcher;
pub use engine::{EngineKind, NativeEngine, PprEngine};
pub use request::{PprRequest, PprResponse, RankedVertex};
pub use server::{Server, ServerConfig};
pub use stats::ServerStats;
