//! [`ScoreBlock`] — the reusable output buffer of the engine API.
//!
//! One batch produces up to κ dense score vectors (one per lane). The seed
//! design allocated a fresh `Vec<Vec<f64>>` per batch; at serving rates that
//! host-side churn is exactly the overhead the paper's §4.2 host/accelerator
//! split warns about. A `ScoreBlock` is a single flat lane-major `f64`
//! buffer that the caller allocates once and every [`run_batch`] call
//! reshapes in place — no steady-state allocation.
//!
//! Ownership contract (DESIGN.md §3):
//!
//! - the **caller** owns the block and reuses it across batches;
//! - the **engine** shapes it via [`ScoreBlock::reset`] to exactly the
//!   batch's lane count (partial batches are first-class: a 3-request batch
//!   on a κ=8 engine yields a 3-lane block), fills every lane, and records
//!   the iteration count;
//! - lanes are read back through zero-copy [`ScoreBlock::lane`] views, and
//!   top-N rankings are extracted without materializing a sorted copy via
//!   [`ScoreBlock::top_n`].
//!
//! [`run_batch`]: super::engine::PprEngine::run_batch

use super::request::RankedVertex;
use crate::graph::VertexId;
use crate::spmv::RankedLanes;

/// A reusable block of dense PPR scores: `lanes × num_vertices`, lane-major
/// (`scores[lane * num_vertices + vertex]`).
///
/// Since the top-K-native datapath (DESIGN.md §9) a block can also hold a
/// **ranked** result — per-lane top-K lists instead of dense vectors — in
/// which case [`ranked_k`](Self::ranked_k) is `Some(K)`, [`top_n`] serves
/// O(K) slices and the dense [`lane`] views are unavailable. `reset`
/// restores dense mode.
///
/// [`top_n`]: Self::top_n
/// [`lane`]: Self::lane
#[derive(Debug, Clone, Default)]
pub struct ScoreBlock {
    lanes: usize,
    num_vertices: usize,
    scores: Vec<f64>,
    iterations: usize,
    rungs: usize,
    /// Per-lane ranked lists; meaningful only while `ranked_k` is `Some`.
    ranked: Vec<Vec<RankedVertex>>,
    ranked_k: Option<usize>,
    writeback_words_saved: u64,
    /// Index scratch for [`Self::top_n_scratch`] / [`Self::rank_in_place`].
    topn_idx: Vec<usize>,
}

impl ScoreBlock {
    /// An empty block; the first [`reset`](Self::reset) shapes it.
    pub fn new() -> Self {
        Self::default()
    }

    /// A block pre-sized for `lanes` lanes over `num_vertices` vertices
    /// (avoids the one growth allocation of a fresh block's first batch).
    pub fn with_capacity(lanes: usize, num_vertices: usize) -> Self {
        let mut block = Self::new();
        block.scores.reserve(lanes * num_vertices);
        block
    }

    /// Reshape for a new batch: `lanes` lanes of `num_vertices` scores,
    /// zero-filled, iteration count cleared. Reuses the existing allocation
    /// whenever it is large enough.
    pub fn reset(&mut self, lanes: usize, num_vertices: usize) {
        self.lanes = lanes;
        self.num_vertices = num_vertices;
        self.scores.clear();
        self.scores.resize(lanes * num_vertices, 0.0);
        self.iterations = 0;
        self.rungs = 1;
        self.ranked_k = None;
        self.writeback_words_saved = 0;
        for lane in &mut self.ranked {
            lane.clear();
        }
    }

    /// Lanes held by the last batch.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Vertices per lane.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Iterations the producing engine executed for the last batch.
    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Record the iteration count (engine side).
    pub fn set_iterations(&mut self, iterations: usize) {
        self.iterations = iterations;
    }

    /// Precision-ladder rungs the producing engine ran for the last batch
    /// (1 for single-precision engines; `reset` restores 1). The serving
    /// layer reports `rungs − 1` as the batch's escalation count.
    pub fn rungs(&self) -> usize {
        self.rungs.max(1)
    }

    /// Record the rung count (ladder engine side).
    pub fn set_rungs(&mut self, rungs: usize) {
        self.rungs = rungs.max(1);
    }

    /// Zero-copy view of lane `k`'s dense scores.
    ///
    /// # Panics
    /// If `k >= self.lanes()`, or if the block holds a ranked-only result
    /// (filled via [`Self::fill_ranked`] — no dense scores exist).
    pub fn lane(&self, k: usize) -> &[f64] {
        assert!(k < self.lanes, "lane {k} out of range ({} lanes)", self.lanes);
        assert!(
            self.scores.len() >= self.lanes * self.num_vertices,
            "dense scores unavailable: block holds a ranked top-K result"
        );
        &self.scores[k * self.num_vertices..(k + 1) * self.num_vertices]
    }

    /// Mutable view of lane `k` (engine side).
    ///
    /// # Panics
    /// If `k >= self.lanes()`, or if the block holds a ranked-only result.
    pub fn lane_mut(&mut self, k: usize) -> &mut [f64] {
        assert!(k < self.lanes, "lane {k} out of range ({} lanes)", self.lanes);
        assert!(
            self.scores.len() >= self.lanes * self.num_vertices,
            "dense scores unavailable: block holds a ranked top-K result"
        );
        &mut self.scores[k * self.num_vertices..(k + 1) * self.num_vertices]
    }

    /// The whole block as one flat lane-major slice.
    pub fn as_flat(&self) -> &[f64] {
        &self.scores
    }

    /// Reshape to `lanes × num_vertices` and fill from a **vertex-major**
    /// buffer (`src[v * stride + lane]`, `stride >= lanes`), converting
    /// each word with `convert` — the one transpose/dequantize kernel
    /// every engine backend shares. `stride` exceeds `lanes` when the
    /// producer padded extra lanes (the PJRT artifacts' static κ).
    pub fn fill_vertex_major<W: Copy>(
        &mut self,
        lanes: usize,
        num_vertices: usize,
        stride: usize,
        src: &[W],
        mut convert: impl FnMut(W) -> f64,
    ) {
        assert!(stride >= lanes, "stride {stride} < lanes {lanes}");
        assert!(src.len() >= num_vertices * stride, "source buffer too short");
        self.reset(lanes, num_vertices);
        for lane in 0..lanes {
            let dst = &mut self.scores[lane * num_vertices..(lane + 1) * num_vertices];
            for (v, slot) in dst.iter_mut().enumerate() {
                *slot = convert(src[v * stride + lane]);
            }
        }
    }

    /// Extract the top-`n` ranking of lane `k` without copying the lane:
    /// descending score, ties toward the lower vertex id, NaN ranked last
    /// (the crate-wide tie-break, `metrics::top_n_by`). `n` is clamped to
    /// `num_vertices`; `n == 0` yields an empty ranking. On a ranked block
    /// this is an O(n) prefix copy of the stored ranking (clamped to its
    /// K entries).
    pub fn top_n(&self, k: usize, n: usize) -> Vec<RankedVertex> {
        if self.ranked_k.is_some() {
            let lane = self.ranked_lane(k);
            return lane[..n.min(lane.len())].to_vec();
        }
        let lane = self.lane(k);
        crate::metrics::top_n_indices_f64(lane, n)
            .into_iter()
            .map(|v| RankedVertex { vertex: v as VertexId, score: lane[v] })
            .collect()
    }

    /// Scratch-reusing [`Self::top_n`] for the serving hot path: the
    /// O(|V|) index buffer is kept inside the block and reused across
    /// calls instead of reallocated per response lane. Only the returned
    /// ranking (which the response owns) is allocated. Ranked blocks are
    /// served as an O(n) prefix copy, same as `top_n`.
    pub fn top_n_scratch(&mut self, k: usize, n: usize) -> Vec<RankedVertex> {
        if self.ranked_k.is_some() {
            let lane = self.ranked_lane(k);
            return lane[..n.min(lane.len())].to_vec();
        }
        assert!(k < self.lanes, "lane {k} out of range ({} lanes)", self.lanes);
        let nv = self.num_vertices;
        let mut idx = std::mem::take(&mut self.topn_idx);
        let lane = &self.scores[k * nv..(k + 1) * nv];
        crate::metrics::top_n_by_into(nv, n, |a, b| crate::metrics::nan_last(lane[a], lane[b]), &mut idx);
        let out = idx
            .iter()
            .map(|&v| RankedVertex { vertex: v as VertexId, score: lane[v] })
            .collect();
        self.topn_idx = idx;
        out
    }

    /// `Some(K)` when the block holds per-lane top-K rankings (the
    /// top-K-native path or [`Self::rank_in_place`]), `None` for dense
    /// blocks. `reset` restores `None`.
    pub fn ranked_k(&self) -> Option<usize> {
        self.ranked_k
    }

    /// Score-vector write-back words the producing engine's pruning
    /// threshold marked skippable (0 for dense blocks and engines without
    /// the native top-K path). See DESIGN.md §9.
    pub fn writeback_words_saved(&self) -> u64 {
        self.writeback_words_saved
    }

    /// Ranked view of lane `k`: descending score, ties toward the lower
    /// vertex id, at most `ranked_k` entries.
    ///
    /// # Panics
    /// If the block is dense (`ranked_k() == None`) or `k` is out of range.
    pub fn ranked_lane(&self, k: usize) -> &[RankedVertex] {
        assert!(self.ranked_k.is_some(), "ranked_lane on a dense block");
        assert!(k < self.lanes, "lane {k} out of range ({} lanes)", self.lanes);
        &self.ranked[k]
    }

    /// Load a top-K-native engine result: `src.lanes.len()` ranked lanes
    /// over `num_vertices` vertices with **no dense scores** — the O(K·κ)
    /// result path that replaces the full dequantize/transpose + per-lane
    /// scan. Lane buffers are reused across batches; iteration/rung
    /// counters are cleared for the engine to set.
    pub fn fill_ranked(&mut self, num_vertices: usize, src: &RankedLanes) {
        let lanes = src.lanes.len();
        self.lanes = lanes;
        self.num_vertices = num_vertices;
        self.scores.clear();
        self.iterations = 0;
        self.rungs = 1;
        self.ranked_k = Some(src.k);
        self.writeback_words_saved = src.writeback_words_saved;
        self.ranked.resize_with(lanes, Vec::new);
        self.ranked.truncate(lanes);
        for (dst, lane) in self.ranked.iter_mut().zip(&src.lanes) {
            dst.clear();
            dst.extend(lane.iter().map(|&(vertex, score)| RankedVertex { vertex, score }));
        }
    }

    /// Rank every dense lane into a top-`k` list and switch the block to
    /// ranked mode (dense scores are retained, so `lane` keeps working).
    /// This is the extract-after fallback used by engines without a native
    /// top-K path; must be called on a dense block.
    pub fn rank_in_place(&mut self, k: usize) {
        assert!(
            self.scores.len() >= self.lanes * self.num_vertices,
            "rank_in_place needs dense scores"
        );
        let nv = self.num_vertices;
        let mut idx = std::mem::take(&mut self.topn_idx);
        let mut ranked = std::mem::take(&mut self.ranked);
        ranked.resize_with(self.lanes, Vec::new);
        ranked.truncate(self.lanes);
        for (lane_i, dst) in ranked.iter_mut().enumerate() {
            let lane = &self.scores[lane_i * nv..(lane_i + 1) * nv];
            crate::metrics::top_n_by_into(
                nv,
                k,
                |a, b| crate::metrics::nan_last(lane[a], lane[b]),
                &mut idx,
            );
            dst.clear();
            dst.extend(idx.iter().map(|&v| RankedVertex { vertex: v as VertexId, score: lane[v] }));
        }
        self.ranked = ranked;
        self.topn_idx = idx;
        self.ranked_k = Some(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(lanes: usize, nv: usize) -> ScoreBlock {
        let mut b = ScoreBlock::new();
        b.reset(lanes, nv);
        for k in 0..lanes {
            for v in 0..nv {
                b.lane_mut(k)[v] = (k * nv + v) as f64;
            }
        }
        b
    }

    #[test]
    fn lane_views_are_disjoint_and_ordered() {
        let b = filled(3, 4);
        assert_eq!(b.lanes(), 3);
        assert_eq!(b.num_vertices(), 4);
        assert_eq!(b.lane(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(b.lane(2), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(b.as_flat().len(), 12);
    }

    #[test]
    fn reset_reuses_and_reshapes() {
        let mut b = filled(4, 8);
        let cap = b.scores.capacity();
        b.reset(2, 8); // shrink: same allocation, stale data zeroed
        assert_eq!(b.lanes(), 2);
        assert_eq!(b.scores.capacity(), cap);
        assert!(b.lane(1).iter().all(|&x| x == 0.0));
        assert_eq!(b.iterations(), 0);
    }

    #[test]
    #[should_panic(expected = "lane 2 out of range")]
    fn lane_out_of_range_panics() {
        let b = filled(2, 4);
        let _ = b.lane(2);
    }

    #[test]
    fn top_n_orders_descending() {
        let mut b = ScoreBlock::new();
        b.reset(1, 4);
        b.lane_mut(0).copy_from_slice(&[0.1, 0.9, 0.5, 0.3]);
        let top = b.top_n(0, 2);
        assert_eq!(top[0], RankedVertex { vertex: 1, score: 0.9 });
        assert_eq!(top[1], RankedVertex { vertex: 2, score: 0.5 });
    }

    #[test]
    fn top_n_ties_break_toward_lower_id() {
        let mut b = ScoreBlock::new();
        b.reset(1, 5);
        b.lane_mut(0).copy_from_slice(&[0.5, 0.9, 0.5, 0.9, 0.1]);
        let top: Vec<u32> = b.top_n(0, 4).iter().map(|r| r.vertex).collect();
        assert_eq!(top, vec![1, 3, 0, 2]);
    }

    #[test]
    fn top_n_handles_nan_lanes() {
        let mut b = ScoreBlock::new();
        b.reset(1, 4);
        b.lane_mut(0).copy_from_slice(&[f64::NAN, 0.2, f64::NAN, 0.7]);
        let top = b.top_n(0, 4);
        // finite scores first, NaN demoted to the tail
        assert_eq!(top[0].vertex, 3);
        assert_eq!(top[1].vertex, 1);
        assert!(top[2].score.is_nan() && top[3].score.is_nan());
    }

    #[test]
    fn top_n_clamps_and_zero() {
        let b = filled(1, 3);
        assert_eq!(b.top_n(0, 10).len(), 3, "n > |V| clamps to |V|");
        assert!(b.top_n(0, 0).is_empty(), "n == 0 yields empty ranking");
    }

    #[test]
    fn fill_vertex_major_transposes() {
        // vertex-major 3 vertices × 2 lanes: [v0l0, v0l1, v1l0, v1l1, ...]
        let src = [10u32, 20, 11, 21, 12, 22];
        let mut b = ScoreBlock::new();
        b.fill_vertex_major(2, 3, 2, &src, |w| w as f64);
        assert_eq!(b.lane(0), &[10.0, 11.0, 12.0]);
        assert_eq!(b.lane(1), &[20.0, 21.0, 22.0]);
    }

    #[test]
    fn fill_vertex_major_skips_padded_lanes() {
        // stride 4 (artifact κ) but only 2 real lanes requested
        let src: Vec<i64> = (0..3 * 4).collect();
        let mut b = ScoreBlock::new();
        b.fill_vertex_major(2, 3, 4, &src, |w| w as f64);
        assert_eq!(b.lanes(), 2);
        assert_eq!(b.lane(0), &[0.0, 4.0, 8.0]);
        assert_eq!(b.lane(1), &[1.0, 5.0, 9.0]);
    }

    #[test]
    fn top_n_scratch_matches_top_n() {
        let mut b = ScoreBlock::new();
        b.reset(2, 6);
        b.lane_mut(0).copy_from_slice(&[0.5, 0.9, 0.5, 0.9, 0.1, f64::NAN]);
        b.lane_mut(1).copy_from_slice(&[0.0, 0.0, 0.3, 0.2, 0.3, 0.1]);
        for lane in 0..2 {
            for n in [0, 1, 3, 6, 10] {
                assert_eq!(b.top_n_scratch(lane, n), b.top_n(lane, n), "lane {lane} n {n}");
            }
        }
    }

    #[test]
    fn fill_ranked_serves_topn_without_dense_scores() {
        let src = crate::spmv::RankedLanes {
            k: 2,
            lanes: vec![vec![(3, 0.9), (0, 0.5)], vec![(1, 0.8), (4, 0.2)]],
            writeback_words_saved: 17,
            saved_per_shard: vec![10, 7],
        };
        let mut b = ScoreBlock::new();
        b.fill_ranked(6, &src);
        assert_eq!(b.lanes(), 2);
        assert_eq!(b.num_vertices(), 6);
        assert_eq!(b.ranked_k(), Some(2));
        assert_eq!(b.writeback_words_saved(), 17);
        assert_eq!(b.top_n(0, 1), vec![RankedVertex { vertex: 3, score: 0.9 }]);
        assert_eq!(b.top_n_scratch(1, 10).len(), 2, "n clamps to the stored K entries");
        assert_eq!(b.ranked_lane(1)[0].vertex, 1);
        assert!(b.as_flat().is_empty(), "ranked fill allocates no dense scores");
    }

    #[test]
    #[should_panic(expected = "dense scores unavailable")]
    fn dense_lane_view_panics_on_ranked_block() {
        let src = crate::spmv::RankedLanes {
            k: 1,
            lanes: vec![vec![(0, 1.0)]],
            writeback_words_saved: 0,
            saved_per_shard: vec![0],
        };
        let mut b = ScoreBlock::new();
        b.fill_ranked(3, &src);
        let _ = b.lane(0);
    }

    #[test]
    fn rank_in_place_matches_dense_top_n_and_reset_restores_dense() {
        let mut b = ScoreBlock::new();
        b.reset(2, 5);
        b.lane_mut(0).copy_from_slice(&[0.5, 0.9, 0.5, 0.9, 0.1]);
        b.lane_mut(1).copy_from_slice(&[0.1, 0.2, 0.3, 0.4, 0.5]);
        let dense: Vec<_> = (0..2).map(|lane| b.top_n(lane, 3)).collect();
        b.rank_in_place(3);
        assert_eq!(b.ranked_k(), Some(3));
        for lane in 0..2 {
            assert_eq!(b.top_n(lane, 3), dense[lane]);
            assert_eq!(b.top_n(lane, 9), dense[lane], "clamped to the K stored entries");
        }
        assert_eq!(b.lane(0)[1], 0.9, "dense scores retained by rank_in_place");
        b.reset(1, 5);
        assert_eq!(b.ranked_k(), None, "reset restores dense mode");
        assert_eq!(b.writeback_words_saved(), 0);
    }

    #[test]
    fn iterations_roundtrip() {
        let mut b = ScoreBlock::new();
        b.reset(1, 1);
        b.set_iterations(7);
        assert_eq!(b.iterations(), 7);
        b.reset(1, 1);
        assert_eq!(b.iterations(), 0, "reset clears iterations");
    }

    #[test]
    fn rungs_roundtrip_and_floor_at_one() {
        let mut b = ScoreBlock::new();
        assert_eq!(b.rungs(), 1, "fresh block reads as single-rung");
        b.reset(1, 1);
        b.set_rungs(3);
        assert_eq!(b.rungs(), 3);
        b.set_rungs(0);
        assert_eq!(b.rungs(), 1, "rung count floors at 1");
        b.reset(1, 1);
        assert_eq!(b.rungs(), 1, "reset restores single-rung");
    }
}
