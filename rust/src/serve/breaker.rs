//! Per-`(graph, class, backend)` circuit breaker (DESIGN.md §10, §12).
//!
//! Classic three-state machine over a sliding window of solve outcomes:
//!
//! - **Closed** — traffic flows; outcomes land in a bounded window. When
//!   the window holds at least `min_samples` outcomes and the failure
//!   fraction reaches `failure_rate`, the breaker opens.
//! - **Open** — requests fast-fail with 503 + `Retry-After` (no queue
//!   slot, no engine lane) until `open_ms` elapses.
//! - **HalfOpen** — up to `half_open_probes` requests are admitted as
//!   probes; that many consecutive successes close the breaker (counting
//!   one full open → half-open → closed **cycle**), any failure re-opens
//!   it. A reserved probe slot must be settled by [`CircuitBreaker::record`]
//!   (outcome observed) or returned by [`CircuitBreaker::release`]
//!   (request shed or abandoned before any solve ran). As a backstop
//!   against leaked slots, a half-open entry whose probe budget has been
//!   fully reserved for longer than `open_for` without an outcome
//!   reclaims one slot for the next `check` — the breaker can always
//!   probe its way back to closed, never wedging at 503 forever.
//!
//! Only *fault* outcomes (engine failures, panics, dead workers —
//! [`ServeError::is_fault`](crate::coordinator::ServeError::is_fault))
//! trip the breaker; deadline misses and validation rejections are the
//! client's problem, not the backend's. The keyed granularity means a
//! graph whose engine is melting down fast-fails alone — other graphs,
//! other accuracy classes, and **other backends** of the same graph keep
//! serving. The backend dimension matters under heterogeneous dispatch
//! (DESIGN.md §12): admission takes the request's *candidate* backend
//! set, and fast-fails only when every candidate's breaker holds the
//! request back — a breaker opened by CPU-baseline failures never
//! fast-fails traffic the dispatcher would route to the healthy native
//! lane. Outcomes are recorded against the backend that actually served
//! (the ticket's attribution stamp).

use crate::coordinator::EngineKind;
use crate::fixed::AccuracyClass;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Breaker tuning knobs (from the `[serve]` config section).
#[derive(Debug, Clone, PartialEq)]
pub struct BreakerConfig {
    /// Sliding-window size, in observed outcomes.
    pub window: usize,
    /// Failure fraction that trips a closed breaker.
    pub failure_rate: f64,
    /// Minimum outcomes in the window before the rate is trusted.
    pub min_samples: usize,
    /// How long an open breaker fast-fails before probing.
    pub open_for: Duration,
    /// Consecutive half-open successes required to close.
    pub half_open_probes: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            window: 32,
            failure_rate: 0.5,
            min_samples: 8,
            open_for: Duration::from_millis(250),
            half_open_probes: 2,
        }
    }
}

impl BreakerConfig {
    /// Lift the breaker knobs out of a full serve configuration.
    pub fn from_serve(cfg: &crate::config::ServeConfig) -> Self {
        Self {
            window: cfg.breaker_window,
            failure_rate: cfg.breaker_failure_rate,
            min_samples: cfg.breaker_min_samples,
            open_for: Duration::from_millis(cfg.breaker_open_ms),
            half_open_probes: cfg.breaker_half_open_probes,
        }
    }
}

/// Observable state of one breaker entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Traffic flows, outcomes are being watched.
    Closed,
    /// Fast-failing; holds until the open interval elapses.
    Open,
    /// Probing with limited admissions.
    HalfOpen,
}

impl BreakerState {
    /// Numeric encoding for the metrics gauge (0/1/2).
    pub fn as_gauge(&self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

#[derive(Debug)]
enum EntryState {
    Closed,
    Open { until: Instant },
    HalfOpen { in_flight: usize, successes: usize, last_admit: Instant },
}

#[derive(Debug)]
struct Entry {
    state: EntryState,
    /// Sliding outcome window (true = failure), closed state only.
    window: VecDeque<bool>,
}

impl Entry {
    fn new() -> Self {
        Self { state: EntryState::Closed, window: VecDeque::new() }
    }
}

/// A successful admission from [`CircuitBreaker::check`]: remembers
/// whether a half-open probe slot was reserved (and on which backend), so
/// the eventual [`record`](CircuitBreaker::record) or
/// [`release`](CircuitBreaker::release) settles exactly that slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Admission {
    /// The backend whose half-open entry reserved a probe slot for this
    /// request; `None` when admission was free (closed / no history).
    pub probe: Option<EngineKind>,
}

impl Admission {
    /// A free admission (no probe slot held).
    pub fn none() -> Self {
        Self::default()
    }
}

/// The breaker table: one entry per `(graph, class, backend)` seen.
#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    inner: Mutex<HashMap<(Arc<str>, AccuracyClass, EngineKind), Entry>>,
    /// Closed → open trips.
    opens: AtomicU64,
    /// Completed open → half-open → closed cycles.
    cycles: AtomicU64,
}

impl CircuitBreaker {
    /// Empty table under `cfg`.
    pub fn new(cfg: BreakerConfig) -> Self {
        Self {
            cfg,
            inner: Mutex::new(HashMap::new()),
            opens: AtomicU64::new(0),
            cycles: AtomicU64::new(0),
        }
    }

    /// Admission check for one request against every backend that could
    /// serve it (the server's candidate set for the request's class; a
    /// static server passes its single backend). Admits when **any**
    /// candidate's breaker lets the request through:
    ///
    /// - a candidate with no history, or closed → free admission;
    /// - otherwise a candidate whose open hold expired (→ half-open) or
    ///   with a free half-open probe slot → admission carrying that
    ///   reserved probe;
    /// - only when *every* candidate holds the request back →
    ///   `Err(min retry_after)`.
    ///
    /// Every admitted request must settle with exactly one
    /// [`record`](Self::record) — or return its slot via
    /// [`release`](Self::release) if it is dropped before any solve runs
    /// — so half-open probe slots are never leaked.
    pub fn check(
        &self,
        graph: &Arc<str>,
        class: AccuracyClass,
        candidates: &[EngineKind],
    ) -> Result<Admission, Duration> {
        if candidates.is_empty() {
            return Ok(Admission::none());
        }
        let mut map = self.inner.lock().unwrap();
        // pass 1: any candidate closed (or never seen) admits for free
        for &kind in candidates {
            match map.get(&(graph.clone(), class, kind)) {
                None => return Ok(Admission::none()),
                Some(entry) if matches!(entry.state, EntryState::Closed) => {
                    return Ok(Admission::none());
                }
                Some(_) => {}
            }
        }
        // pass 2: reserve a probe on the first candidate that offers one
        let mut min_retry: Option<Duration> = None;
        for &kind in candidates {
            let entry = map
                .get_mut(&(graph.clone(), class, kind))
                .expect("pass 1 saw every candidate");
            match &mut entry.state {
                EntryState::Closed => unreachable!("closed admitted in pass 1"),
                EntryState::Open { until } => {
                    let now = Instant::now();
                    if now < *until {
                        let retry = *until - now;
                        min_retry = Some(min_retry.map_or(retry, |m| m.min(retry)));
                    } else {
                        entry.state = EntryState::HalfOpen {
                            in_flight: 1,
                            successes: 0,
                            last_admit: now,
                        };
                        return Ok(Admission { probe: Some(kind) });
                    }
                }
                EntryState::HalfOpen { in_flight, last_admit, .. } => {
                    let now = Instant::now();
                    if *in_flight < self.cfg.half_open_probes {
                        *in_flight += 1;
                        *last_admit = now;
                        return Ok(Admission { probe: Some(kind) });
                    } else if now.duration_since(*last_admit) >= self.cfg.open_for {
                        // every probe slot has been reserved for a full
                        // hold interval with no outcome: the slots leaked
                        // (request shed downstream, ticket abandoned).
                        // Hand one to this request so the breaker can
                        // still recover instead of fast-failing forever.
                        *last_admit = now;
                        return Ok(Admission { probe: Some(kind) });
                    } else {
                        // probes are out; hold the rest back briefly
                        let retry = self.cfg.open_for;
                        min_retry = Some(min_retry.map_or(retry, |m| m.min(retry)));
                    }
                }
            }
        }
        Err(min_retry.unwrap_or(self.cfg.open_for))
    }

    /// Record the outcome of an admitted request (`failure` = a backend
    /// fault, not a client error). `backend` is who actually served —
    /// the ticket's attribution stamp — falling back to the admission's
    /// probe backend when no solve ever stamped one. If the request was
    /// admitted as a probe on one backend but served by another (the
    /// dispatcher rerouted it), the unused probe slot is returned first
    /// so it is never leaked.
    pub fn record(
        &self,
        graph: &Arc<str>,
        class: AccuracyClass,
        backend: Option<EngineKind>,
        admission: Admission,
        failure: bool,
    ) {
        let Some(target) = backend.or(admission.probe) else {
            // freely-admitted request that never reached a solve: nothing
            // to attribute the outcome to
            return;
        };
        let mut map = self.inner.lock().unwrap();
        if let Some(probe) = admission.probe {
            if probe != target {
                if let Some(entry) = map.get_mut(&(graph.clone(), class, probe)) {
                    if let EntryState::HalfOpen { in_flight, .. } = &mut entry.state {
                        *in_flight = in_flight.saturating_sub(1);
                    }
                }
            }
        }
        let entry = map.entry((graph.clone(), class, target)).or_insert_with(Entry::new);
        match &mut entry.state {
            EntryState::Closed => {
                entry.window.push_back(failure);
                while entry.window.len() > self.cfg.window {
                    entry.window.pop_front();
                }
                if entry.window.len() >= self.cfg.min_samples {
                    let fails = entry.window.iter().filter(|&&f| f).count();
                    if fails as f64 >= self.cfg.failure_rate * entry.window.len() as f64 {
                        entry.state =
                            EntryState::Open { until: Instant::now() + self.cfg.open_for };
                        entry.window.clear();
                        self.opens.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            EntryState::Open { .. } => {
                // a straggler finishing after the trip: no state change
            }
            EntryState::HalfOpen { in_flight, successes, .. } => {
                if failure {
                    entry.state =
                        EntryState::Open { until: Instant::now() + self.cfg.open_for };
                    self.opens.fetch_add(1, Ordering::Relaxed);
                } else {
                    *successes += 1;
                    *in_flight = in_flight.saturating_sub(1);
                    if *successes >= self.cfg.half_open_probes {
                        entry.state = EntryState::Closed;
                        self.cycles.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }

    /// Return an admission reserved by [`check`](Self::check) without
    /// recording an outcome: the request was shed or abandoned before any
    /// solve ran, so it says nothing about backend health. Only a
    /// half-open probe slot holds state to return; a free admission is a
    /// no-op.
    pub fn release(&self, graph: &Arc<str>, class: AccuracyClass, admission: Admission) {
        let Some(probe) = admission.probe else { return };
        let mut map = self.inner.lock().unwrap();
        if let Some(entry) = map.get_mut(&(graph.clone(), class, probe)) {
            if let EntryState::HalfOpen { in_flight, .. } = &mut entry.state {
                *in_flight = in_flight.saturating_sub(1);
            }
        }
    }

    /// Closed → open transitions so far.
    pub fn opens(&self) -> u64 {
        self.opens.load(Ordering::Relaxed)
    }

    /// Completed open → half-open → closed recovery cycles so far.
    pub fn cycles(&self) -> u64 {
        self.cycles.load(Ordering::Relaxed)
    }

    /// Current state per `(graph, class, backend)`, for the metrics
    /// exposition.
    pub fn states(&self) -> Vec<(Arc<str>, AccuracyClass, EngineKind, BreakerState)> {
        let map = self.inner.lock().unwrap();
        let mut out: Vec<_> = map
            .iter()
            .map(|((g, c, k), e)| {
                let state = match e.state {
                    EntryState::Closed => BreakerState::Closed,
                    EntryState::Open { until } => {
                        // report what a check() would do, so the gauge
                        // never shows "open" past the hold interval
                        if Instant::now() < until {
                            BreakerState::Open
                        } else {
                            BreakerState::HalfOpen
                        }
                    }
                    EntryState::HalfOpen { .. } => BreakerState::HalfOpen,
                };
                (g.clone(), *c, *k, state)
            })
            .collect();
        out.sort_by(|a, b| {
            (a.0.as_ref(), a.1.label(), a.2.label()).cmp(&(b.0.as_ref(), b.1.label(), b.2.label()))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NATIVE: &[EngineKind] = &[EngineKind::Native];

    fn key() -> Arc<str> {
        Arc::from("g")
    }

    fn quick_cfg() -> BreakerConfig {
        BreakerConfig {
            window: 8,
            failure_rate: 0.5,
            min_samples: 4,
            open_for: Duration::from_millis(30),
            half_open_probes: 2,
        }
    }

    /// Record an outcome against the native backend with no probe held —
    /// the shape of a freely-admitted request on a static server.
    fn record_native(b: &CircuitBreaker, g: &Arc<str>, failure: bool) {
        b.record(g, AccuracyClass::Exact, Some(EngineKind::Native), Admission::none(), failure);
    }

    #[test]
    fn stays_closed_under_healthy_traffic() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..64 {
            assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
            record_native(&b, &g, false);
        }
        assert_eq!(b.opens(), 0);
        assert_eq!(b.states()[0].3, BreakerState::Closed);
    }

    #[test]
    fn opens_on_failure_rate_and_isolates_key() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        assert_eq!(b.opens(), 1);
        let err = b.check(&g, AccuracyClass::Exact, NATIVE).unwrap_err();
        assert!(err <= Duration::from_millis(30));
        // other classes and graphs are unaffected
        assert!(b.check(&g, AccuracyClass::Fast, NATIVE).is_ok());
        assert!(b.check(&Arc::from("other"), AccuracyClass::Exact, NATIVE).is_ok());
    }

    #[test]
    fn full_cycle_open_half_open_closed() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_err(), "open fast-fails");
        std::thread::sleep(Duration::from_millis(35));
        // hold expired: probes are admitted, up to the configured count
        let p1 = b.check(&g, AccuracyClass::Exact, NATIVE).unwrap();
        assert_eq!(p1.probe, Some(EngineKind::Native), "probe admission is stamped");
        let p2 = b.check(&g, AccuracyClass::Exact, NATIVE).unwrap();
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_err(), "probe budget spent");
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), p1, false);
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), p2, false);
        assert_eq!(b.cycles(), 1, "two probe successes close the breaker");
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        assert_eq!(b.states()[0].3, BreakerState::Closed);
    }

    #[test]
    fn half_open_failure_reopens() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        std::thread::sleep(Duration::from_millis(35));
        let probe = b.check(&g, AccuracyClass::Exact, NATIVE).unwrap();
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), probe, true);
        assert_eq!(b.opens(), 2, "probe failure re-opens");
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_err());
        assert_eq!(b.cycles(), 0);
    }

    #[test]
    fn release_returns_probe_slot_without_outcome() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        std::thread::sleep(Duration::from_millis(35));
        // both probe slots reserved, then one request is shed downstream
        let shed = b.check(&g, AccuracyClass::Exact, NATIVE).unwrap();
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_err(), "budget spent");
        b.release(&g, AccuracyClass::Exact, shed);
        // the returned slot admits the next probe immediately
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        // releasing never counts as a probe outcome
        assert_eq!(b.cycles(), 0);
        assert_eq!(b.states()[0].3, BreakerState::HalfOpen);
        // a free admission ignores release entirely
        b.release(&Arc::from("other"), AccuracyClass::Exact, Admission::none());
        assert!(b.check(&Arc::from("other"), AccuracyClass::Exact, NATIVE).is_ok());
    }

    #[test]
    fn leaked_probe_slots_are_reclaimed_after_hold_interval() {
        // regression: a probe slot whose request never settled (shed by
        // admission, abandoned async ticket) used to wedge the key at 503
        // forever — half-open had no timeout and check() fast-failed once
        // in_flight hit the budget
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        std::thread::sleep(Duration::from_millis(35));
        // reserve the full probe budget and leak it (no record, no release)
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_err(), "budget spent");
        // after a full hold interval with no outcome a slot is reclaimed
        std::thread::sleep(Duration::from_millis(35));
        let p1 = b
            .check(&g, AccuracyClass::Exact, NATIVE)
            .expect("leaked slot reclaimed");
        // two recorded successes still close the breaker normally
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), p1, false);
        record_native(&b, &g, false);
        assert_eq!(b.cycles(), 1);
        assert!(b.check(&g, AccuracyClass::Exact, NATIVE).is_ok());
        assert_eq!(b.states()[0].3, BreakerState::Closed);
    }

    #[test]
    fn window_slides_old_failures_out() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        // 3 failures, then a steady stream of successes: the failures age
        // out of the 8-deep window before min_samples worth of rate can
        // trip anything
        for _ in 0..3 {
            record_native(&b, &g, true);
        }
        for _ in 0..16 {
            record_native(&b, &g, false);
        }
        assert_eq!(b.opens(), 0);
    }

    #[test]
    fn open_backend_never_blocks_healthy_candidates() {
        // regression (DESIGN.md §12): a breaker tripped by CPU-baseline
        // failures must not fast-fail requests the dispatcher can route to
        // the healthy native lane — admission checks the whole candidate
        // set, and fast-fails only when every candidate holds back
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            b.record(
                &g,
                AccuracyClass::Exact,
                Some(EngineKind::CpuBaseline),
                Admission::none(),
                true,
            );
        }
        assert_eq!(b.opens(), 1);
        // CPU alone is held back...
        assert!(b.check(&g, AccuracyClass::Exact, &[EngineKind::CpuBaseline]).is_err());
        // ...but the heterogeneous candidate set still admits for free
        let admission = b
            .check(&g, AccuracyClass::Exact, &[EngineKind::CpuBaseline, EngineKind::Native])
            .expect("healthy native candidate admits");
        assert_eq!(admission.probe, None, "no probe slot consumed on the open entry");
        // the served outcome lands on the backend that actually ran
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), admission, false);
        let states = b.states();
        assert!(states
            .iter()
            .any(|(_, _, k, s)| *k == EngineKind::Native && *s == BreakerState::Closed));
        assert!(states
            .iter()
            .any(|(_, _, k, s)| *k == EngineKind::CpuBaseline && *s == BreakerState::Open));
    }

    #[test]
    fn rerouted_probe_slot_is_returned() {
        // a probe reserved on one backend but served by another must give
        // the slot back so the probing entry can keep recovering
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        for _ in 0..4 {
            b.record(
                &g,
                AccuracyClass::Exact,
                Some(EngineKind::CpuBaseline),
                Admission::none(),
                true,
            );
        }
        // trip native too, so pass 1 can't admit for free
        for _ in 0..4 {
            record_native(&b, &g, true);
        }
        std::thread::sleep(Duration::from_millis(35));
        let both = &[EngineKind::CpuBaseline, EngineKind::Native];
        let p1 = b.check(&g, AccuracyClass::Exact, both).unwrap();
        assert_eq!(p1.probe, Some(EngineKind::CpuBaseline), "first candidate probes first");
        let p2 = b.check(&g, AccuracyClass::Exact, both).unwrap();
        assert_eq!(p2.probe, Some(EngineKind::CpuBaseline));
        // CPU budget spent: the next admission probes the native entry
        let p3 = b.check(&g, AccuracyClass::Exact, both).unwrap();
        assert_eq!(p3.probe, Some(EngineKind::Native));
        // p1 reroutes to native: its CPU slot comes back, outcome lands on
        // native's window-less half-open entry
        b.record(&g, AccuracyClass::Exact, Some(EngineKind::Native), p1, false);
        let p4 = b.check(&g, AccuracyClass::Exact, &[EngineKind::CpuBaseline]).unwrap();
        assert_eq!(p4.probe, Some(EngineKind::CpuBaseline), "returned slot admits again");
    }

    #[test]
    fn empty_candidate_set_admits_freely() {
        let b = CircuitBreaker::new(quick_cfg());
        let g = key();
        assert_eq!(b.check(&g, AccuracyClass::Exact, &[]), Ok(Admission::none()));
    }
}
