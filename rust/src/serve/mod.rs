//! L4 HTTP front door — the network edge in front of the L3 coordinator
//! (DESIGN.md §8).
//!
//! The coordinator ([`crate::coordinator`]) is an in-process API: callers
//! hold a [`Server`] and submit typed requests. This module puts a wire
//! protocol in front of it so the deployment story of the paper's
//! use-case (§1: ranking "while users interact" with a social network or
//! shop) is closed end-to-end: accept sockets, parse JSON, admit or shed,
//! run the query, expose the counters Prometheus scrapes.
//!
//! Layering (one direction, no cycles):
//!
//! - [`http`] — HTTP/1.1 framing over `std::net` (no HTTP crate vendored);
//! - [`prom`] — metric registry + text exposition + a tiny validator;
//! - [`admission`] — bounded per-graph queues with class-ordered shedding;
//! - [`breaker`] — per-`(graph, class)` circuit breakers that fast-fail
//!   requests to a failing backend (DESIGN.md §10);
//! - [`state`] — shared handles ([`ServeState`]) and the async
//!   [`TicketStore`];
//! - [`handlers`] — route dispatch, JSON mapping, status taxonomy;
//! - [`FrontDoor`] (here) — acceptor thread + connection workers;
//! - [`loadgen`] — the benchmark client (open-loop Poisson arrivals).
//!
//! Threading: one acceptor thread owns the listener; each accepted
//! connection becomes a detached task on a **dedicated**
//! [`WorkerPool`] — never the global compute pool, where long-lived
//! connection handlers would starve engine fan-outs
//! (`runtime::pool::global`). Handlers service keep-alive connections
//! with a short poll interval so shutdown is bounded: every worker
//! notices the stop flag within [`IDLE_POLL`] and exits; the pool's drop
//! then joins them.

pub mod admission;
pub mod breaker;
pub mod handlers;
pub mod http;
pub mod loadgen;
pub mod prom;
pub mod state;

pub use admission::{Admission, AdmitGuard, Shed};
pub use breaker::{BreakerConfig, BreakerState, CircuitBreaker};
pub use http::{Request, Response};
pub use loadgen::{ClassStats, LoadReport, LoadSpec};
pub use prom::{validate_exposition, CoreHealth, HttpMetrics, LATENCY_BUCKETS_S};
pub use state::{PollOutcome, ServeState, TicketStore};

use crate::coordinator::server::Server;
use crate::runtime::pool::WorkerPool;
use anyhow::{Context, Result};
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How often an idle connection handler checks the stop flag. Bounds
/// both shutdown latency and the busy-wait cost of parked keep-alive
/// connections (one `peek` per tick).
const IDLE_POLL: Duration = Duration::from_millis(100);

/// Read timeout once a request has started arriving: a client that
/// stalls mid-request is cut off instead of pinning a worker.
const REQUEST_READ_TIMEOUT: Duration = Duration::from_secs(10);

/// The running HTTP front door: an acceptor thread plus a dedicated
/// worker pool of connection handlers. Dropping it (or calling
/// [`FrontDoor::shutdown`]) stops accepting, drains the workers, and
/// joins every thread — it does **not** shut the underlying [`Server`]
/// down; that remains the owner's call.
pub struct FrontDoor {
    state: Arc<ServeState>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    pool: Arc<WorkerPool>,
}

impl FrontDoor {
    /// Bind `state.cfg.listen` and start serving. With port 0 the OS
    /// picks a free port — [`FrontDoor::addr`] reports the bound one
    /// (tests and the bench harness rely on this).
    pub fn serve(state: ServeState) -> Result<FrontDoor> {
        let listener = TcpListener::bind(&state.cfg.listen)
            .with_context(|| format!("bind {}", state.cfg.listen))?;
        let addr = listener.local_addr().context("resolve listen address")?;
        let state = Arc::new(state);
        let pool = Arc::new(WorkerPool::new(state.cfg.http_workers.max(1)));
        let stop = Arc::new(AtomicBool::new(false));

        let acceptor = {
            let state = state.clone();
            let pool = pool.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("ppr-http-acceptor".to_string())
                .spawn(move || acceptor_loop(&listener, &state, &pool, &stop))
                .context("spawn acceptor")?
        };
        Ok(FrontDoor { state, addr, stop, acceptor: Some(acceptor), pool })
    }

    /// The bound socket address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared serving state (metrics, admission, tickets).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Stop accepting, drain connection handlers, join all threads.
    pub fn shutdown(self) {
        // Drop does the work; consuming `self` makes the intent explicit
        // at call sites.
    }

    fn stop_now(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // unblock the acceptor's blocking accept() with a throwaway
        // connection; it re-checks the flag on wake-up
        let _ = TcpStream::connect(self.addr);
        if let Some(handle) = self.acceptor.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        self.stop_now();
        // `pool` (an Arc field) drops after this body: the last reference
        // joins the connection workers, each of which exits within
        // IDLE_POLL of the stop flag
    }
}

fn acceptor_loop(
    listener: &TcpListener,
    state: &Arc<ServeState>,
    pool: &Arc<WorkerPool>,
    stop: &Arc<AtomicBool>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let state = state.clone();
                let stop = stop.clone();
                pool.submit(move || connection_loop(stream, &state, &stop));
            }
            Err(_) => {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                // transient accept failure (EMFILE, aborted handshake):
                // back off briefly instead of spinning
                std::thread::sleep(IDLE_POLL);
            }
        }
    }
}

/// Service one keep-alive connection until the peer closes, an error
/// occurs, or the front door stops. Between requests the handler polls
/// with a short-timeout `peek` so a parked connection neither blocks
/// shutdown nor burns a worker on a tight loop.
fn connection_loop(mut stream: TcpStream, state: &ServeState, stop: &AtomicBool) {
    if stream.set_read_timeout(Some(IDLE_POLL)).is_err() {
        return;
    }
    let mut probe = [0u8; 1];
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        match stream.peek(&mut probe) {
            Ok(0) => return, // peer closed
            Ok(_) => {}      // request bytes waiting
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        }

        if stream.set_read_timeout(Some(REQUEST_READ_TIMEOUT)).is_err() {
            return;
        }
        let req = match http::read_request(&mut stream) {
            Ok(Some(req)) => req,
            Ok(None) => return,
            Err(e) => {
                // parse failures are answered when possible, then the
                // connection is dropped (framing state is unknown)
                let _ = Response::error(400, &format!("{e:#}")).write_to(&mut stream, true);
                return;
            }
        };
        let close = req.wants_close();
        let resp = handlers::handle(state, &req);
        if resp.write_to(&mut stream, close).is_err() || close {
            return;
        }
        if stream.set_read_timeout(Some(IDLE_POLL)).is_err() {
            return;
        }
    }
}

/// Join helper for owners that hold the core [`Server`] behind an `Arc`:
/// stop the front door first, then shut the server down if this was the
/// last reference.
pub fn shutdown_stack(front: FrontDoor, server: Arc<Server>) {
    front.shutdown();
    if let Ok(server) = Arc::try_unwrap(server) {
        server.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::http::{format_request, roundtrip};
    use super::*;
    use crate::config::{RunConfig, ServeConfig};
    use crate::coordinator::builder::EngineBuilder;
    use crate::coordinator::registry::GraphRegistry;
    use crate::fixed::Precision;
    use crate::util::Json;
    use std::io::{Read, Write};

    /// Registry-backed server + front door on an ephemeral port.
    fn stack(queue_cap: usize, batch_timeout_ms: u64) -> (FrontDoor, Arc<Server>) {
        let registry = Arc::new(GraphRegistry::new(4));
        let g = crate::graph::generators::watts_strogatz(128, 4, 0.2, 7);
        registry.register_graph("ws", g).expect("register");
        let cfg = RunConfig {
            precision: Precision::Fixed(26),
            kappa: 2,
            iterations: 4,
            batch_timeout_ms,
            num_shards: 1,
            ..Default::default()
        };
        let server = Arc::new(
            EngineBuilder::native()
                .config(cfg)
                .serve_registry(registry.clone(), 1)
                .expect("server starts"),
        );
        let serve_cfg = ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            http_workers: 4,
            queue_cap,
            ..Default::default()
        };
        let state = ServeState::new(server.clone(), registry, serve_cfg);
        let front = FrontDoor::serve(state).expect("front door binds");
        (front, server)
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        let (status, body) =
            roundtrip(&mut conn, &format_request("GET", path, "test", None)).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        let (status, body) =
            roundtrip(&mut conn, &format_request("POST", path, "test", Some(body))).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn healthz_and_graph_listing_over_the_wire() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));

        let (status, body) = get(addr, "/v1/graphs");
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        let graphs = doc.get("graphs").and_then(Json::as_array).unwrap();
        assert_eq!(graphs.len(), 1);
        assert_eq!(graphs[0].get("name").and_then(Json::as_str), Some("ws"));
        assert_eq!(graphs[0].get("num_vertices").and_then(Json::as_u64), Some(128));

        // dispatch surface round-trips: a statically-routed server reports
        // its policy and exactly one available backend
        let dispatch = doc.get("dispatch").expect("dispatch object present");
        assert_eq!(dispatch.get("policy").and_then(Json::as_str), Some("static"));
        let backends = dispatch.get("backends").and_then(Json::as_array).unwrap();
        assert_eq!(backends.len(), 3, "every known backend is listed");
        for b in backends {
            let name = b.get("backend").and_then(Json::as_str).unwrap();
            let up = b.get("available").and_then(Json::as_bool).unwrap();
            assert_eq!(up, name == "native", "static native server: only native is up ({name})");
        }

        shutdown_stack(front, server);
    }

    #[test]
    fn http_query_matches_in_process_query_bit_identically() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        // no explicit class: both paths run the server's default class,
        // so the comparison below is engine-for-engine
        let (status, body) = post(addr, "/v1/graphs/ws/query", r#"{"vertex":5,"top_n":8}"#);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        let results = doc.get("results").and_then(Json::as_array).unwrap();
        assert_eq!(results.len(), 1);
        let ranking = results[0].get("ranking").and_then(Json::as_array).unwrap();
        assert_eq!(ranking.len(), 8);

        // the acceptance gate: scores over the wire are bit-identical to
        // the in-process API (shortest-round-trip JSON floats)
        let reference = server.query_graph("ws", 5, 8).expect("in-process query");
        for (wire, local) in ranking.iter().zip(&reference.ranking) {
            assert_eq!(
                wire.get("vertex").and_then(Json::as_u64),
                Some(u64::from(local.vertex))
            );
            let wire_score = wire.get("score").and_then(Json::as_f64).unwrap();
            assert_eq!(
                wire_score.to_bits(),
                local.score.to_bits(),
                "score drifted across JSON: {wire_score} vs {}",
                local.score
            );
        }
        shutdown_stack(front, server);
    }

    #[test]
    fn error_paths_map_to_honest_statuses() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        for (path, body, want, needle) in [
            ("/v1/graphs/nope/query", r#"{"vertex":1}"#, 404, "unknown graph"),
            ("/v1/graphs/ws/query", r#"{"vertex":1,"top_n":0}"#, 400, "top_n"),
            ("/v1/graphs/ws/query", r#"{"top_n":3}"#, 400, "vertices"),
            ("/v1/graphs/ws/query", r#"{"vertices":[]}"#, 400, "empty"),
            ("/v1/graphs/ws/query", r#"{"vertex":128}"#, 400, "out of range"),
            ("/v1/graphs/ws/query", r#"{"vertex":1,"class":"turbo"}"#, 400, "unknown accuracy"),
            ("/v1/graphs/ws/query", "{not json", 400, "malformed"),
            ("/v1/graphs/ws/submit", r#"{"vertices":[1,2]}"#, 400, "exactly one"),
        ] {
            let (status, resp) = post(addr, path, body);
            assert_eq!(status, want, "{path} {body} → {resp}");
            assert!(resp.contains(needle), "{path} {body} → {resp}");
        }

        let (status, _) = get(addr, "/v1/graphs/ws/query");
        assert_eq!(status, 405, "GET on a POST route");
        let (status, _) = get(addr, "/v1/tickets/not-a-number");
        assert_eq!(status, 400);
        let (status, _) = get(addr, "/v1/tickets/999999");
        assert_eq!(status, 404);
        let (status, _) = get(addr, "/nowhere");
        assert_eq!(status, 404);

        shutdown_stack(front, server);
    }

    /// Satellite: `validate_query` clamps an oversized `top_n` to |V|, so
    /// an HTTP request for more rows than the graph has vertices succeeds
    /// with exactly |V| rows rather than erroring or over-promising.
    #[test]
    fn oversized_top_n_clamps_to_vertex_count_over_http() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        let (status, body) = post(addr, "/v1/graphs/ws/query", r#"{"vertex":5,"top_n":5000}"#);
        assert_eq!(status, 200, "{body}");
        let doc = Json::parse(&body).unwrap();
        let results = doc.get("results").and_then(Json::as_array).unwrap();
        let ranking = results[0].get("ranking").and_then(Json::as_array).unwrap();
        assert_eq!(ranking.len(), 128, "clamped to |V|, not the requested 5000");

        shutdown_stack(front, server);
    }

    #[test]
    fn submit_then_poll_roundtrip() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        let (status, body) =
            post(addr, "/v1/graphs/ws/submit", r#"{"vertex":3,"top_n":4,"class":"static"}"#);
        assert_eq!(status, 202, "{body}");
        let id = Json::parse(&body).unwrap().get("ticket").and_then(Json::as_u64).unwrap();

        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let result = loop {
            let (status, body) = get(addr, &format!("/v1/tickets/{id}"));
            assert_eq!(status, 200, "{body}");
            let doc = Json::parse(&body).unwrap();
            match doc.get("status").and_then(Json::as_str) {
                Some("pending") => {
                    assert!(std::time::Instant::now() < deadline, "ticket never resolved");
                    std::thread::sleep(Duration::from_millis(5));
                }
                Some("done") => break doc,
                other => panic!("unexpected poll status {other:?} in {body}"),
            }
        };
        let vertex = result.get("result").and_then(|r| r.get("vertex")).and_then(Json::as_u64);
        assert_eq!(vertex, Some(3));

        // consumed: a second poll is a 404 and the admission slot is free
        let (status, _) = get(addr, &format!("/v1/tickets/{id}"));
        assert_eq!(status, 404);
        assert!(front.state().tickets.is_empty());

        shutdown_stack(front, server);
    }

    #[test]
    fn overload_sheds_with_retry_after() {
        // queue_cap 1 → every class's limit is 1; a single slow in-flight
        // request (the κ=2 batch waits out the 300 ms flush timeout)
        // forces the next one to shed
        let (front, server) = stack(1, 300);
        let addr = front.addr();

        let slow = std::thread::spawn(move || {
            post(addr, "/v1/graphs/ws/query", r#"{"vertex":1,"top_n":3}"#)
        });
        // let the slow request claim the admission slot
        std::thread::sleep(Duration::from_millis(80));

        // raw exchange so the Retry-After header is visible
        let mut conn = TcpStream::connect(addr).unwrap();
        let body = r#"{"vertex":2,"top_n":3}"#;
        let raw = format!(
            "POST /v1/graphs/ws/query HTTP/1.1\r\nhost: t\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        );
        conn.write_all(raw.as_bytes()).unwrap();
        let mut text = String::new();
        conn.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 429"), "{text}");
        assert!(text.contains("retry-after:"), "{text}");

        let (status, body) = slow.join().unwrap();
        assert_eq!(status, 200, "the in-flight request still completes: {body}");

        shutdown_stack(front, server);
    }

    #[test]
    fn metrics_render_valid_exposition_with_traffic() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();

        let (status, _) = post(addr, "/v1/graphs/ws/query", r#"{"vertex":9,"top_n":3}"#);
        assert_eq!(status, 200);
        let (status, _) = post(addr, "/v1/graphs/nope/query", r#"{"vertex":1}"#);
        assert_eq!(status, 404);

        let (status, text) = get(addr, "/metrics");
        assert_eq!(status, 200);
        let samples = validate_exposition(&text).expect("exposition parses");
        assert!(samples > 0, "exposition carries samples");
        assert!(text.contains("ppr_http_requests_total"), "{text}");
        assert!(text.contains("graph=\"ws\""), "{text}");
        assert!(text.contains("ppr_http_request_duration_seconds_bucket"), "{text}");
        assert!(text.contains("ppr_http_queue_depth"), "{text}");
        // registry residency families (DESIGN.md §11): the query above
        // resolved "ws", so at least one entry is RAM-resident
        assert!(text.contains("ppr_registry_resident_ram 1"), "{text}");
        assert!(text.contains("ppr_registry_resident_disk 0"), "{text}");
        assert!(text.contains("ppr_registry_capacity"), "{text}");
        assert!(text.contains("ppr_registry_artifact_hits_total{graph=\"ws\"} 0"), "{text}");

        shutdown_stack(front, server);
    }

    #[test]
    fn keep_alive_connection_serves_many_queries() {
        let (front, server) = stack(16, 1);
        let mut conn = TcpStream::connect(front.addr()).unwrap();
        for vertex in [1u32, 2, 3] {
            let body = format!("{{\"vertex\":{vertex},\"top_n\":2}}");
            let req = format_request("POST", "/v1/graphs/ws/query", "t", Some(&body));
            let (status, resp) = roundtrip(&mut conn, &req).unwrap();
            assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
        }
        drop(conn);
        shutdown_stack(front, server);
    }

    #[test]
    fn shutdown_refuses_new_connections() {
        let (front, server) = stack(16, 1);
        let addr = front.addr();
        let (status, _) = get(addr, "/healthz");
        assert_eq!(status, 200);
        shutdown_stack(front, server);
        // the listener is gone: the connect is refused outright, or (if a
        // race let it through) the exchange yields no response bytes
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut conn) => {
                let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
                let _ = conn.write_all(&format_request("GET", "/healthz", "t", None));
                let mut buf = String::new();
                let read = conn.read_to_string(&mut buf);
                assert!(
                    read.is_err() || buf.is_empty(),
                    "no front door should answer after shutdown: {buf}"
                );
            }
        }
    }
}
