//! Admission control between the socket and the serving core: bounded
//! per-graph in-flight accounting with class-ordered load shedding.
//!
//! Policy (DESIGN.md §8): each graph gets one budget of `queue_cap`
//! admitted-but-unfinished requests. A request of class `c` is admitted
//! iff the graph's **total** in-flight count is below
//! `ceil(queue_cap × shed_fraction(c))`, where the fractions are ordered
//! `fast ≤ balanced ≤ exact` (`static` shares `exact`'s fraction — it is
//! the paper's fixed-precision baseline, not a degradable tier). Under
//! load the queue therefore fills past the `fast` threshold first: `fast`
//! requests shed (HTTP 429 + `Retry-After`) while `balanced` and `exact`
//! still admit, then `balanced` sheds, and `exact` only when the queue is
//! truly full — overload degrades rank quality before it collapses
//! latency.
//!
//! Admission is RAII: [`Admission::try_admit`] returns an [`AdmitGuard`]
//! that decrements the in-flight count on drop, so every exit path
//! (served, deadline-missed, handler panic) releases its slot.

use crate::config::ServeConfig;
use crate::fixed::AccuracyClass;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Index of a class in per-graph count arrays (`AccuracyClass::all()`
/// order: static, fast, balanced, exact).
fn class_index(class: AccuracyClass) -> usize {
    match class {
        AccuracyClass::Static => 0,
        AccuracyClass::Fast => 1,
        AccuracyClass::Balanced => 2,
        AccuracyClass::Exact => 3,
    }
}

#[derive(Debug, Default)]
struct Counts {
    /// In-flight per class, [`AccuracyClass::all`] order.
    per_class: [usize; 4],
}

impl Counts {
    fn total(&self) -> usize {
        self.per_class.iter().sum()
    }
}

#[derive(Debug)]
struct Inner {
    /// `graph → in-flight counts`. Entries persist once created (the
    /// graph set is small and bounded by the registry).
    depths: Mutex<BTreeMap<String, Counts>>,
    /// Admission threshold per class (absolute request counts).
    limits: [usize; 4],
    retry_after_ms: u64,
}

/// The admission controller. Cheap to clone (shared state).
#[derive(Debug, Clone)]
pub struct Admission {
    inner: Arc<Inner>,
}

impl Admission {
    /// Build from the `[serve]` config (assumed validated).
    pub fn new(cfg: &ServeConfig) -> Self {
        let limit = |frac: f64| -> usize {
            (((cfg.queue_cap as f64) * frac).ceil() as usize).max(1)
        };
        Self {
            inner: Arc::new(Inner {
                depths: Mutex::new(BTreeMap::new()),
                limits: [
                    limit(cfg.shed_exact), // static shares exact's fraction
                    limit(cfg.shed_fast),
                    limit(cfg.shed_balanced),
                    limit(cfg.shed_exact),
                ],
                retry_after_ms: cfg.retry_after_ms,
            }),
        }
    }

    /// The admission threshold of `class` (diagnostics/tests).
    pub fn limit(&self, class: AccuracyClass) -> usize {
        self.inner.limits[class_index(class)]
    }

    /// `Retry-After` hint for shed responses, in milliseconds.
    pub fn retry_after_ms(&self) -> u64 {
        self.inner.retry_after_ms
    }

    /// Try to admit one request of `class` on `graph`. `Err(Shed)` means
    /// the caller must answer 429; on success the returned guard holds
    /// the slot until dropped.
    pub fn try_admit(&self, graph: &str, class: AccuracyClass) -> Result<AdmitGuard, Shed> {
        let idx = class_index(class);
        let mut depths = self.inner.depths.lock().unwrap();
        let counts = depths.entry(graph.to_string()).or_default();
        if counts.total() >= self.inner.limits[idx] {
            return Err(Shed { retry_after_ms: self.inner.retry_after_ms });
        }
        counts.per_class[idx] += 1;
        drop(depths);
        Ok(AdmitGuard { inner: self.inner.clone(), graph: graph.to_string(), idx })
    }

    /// Current in-flight count for `(graph, class)`.
    pub fn depth(&self, graph: &str, class: AccuracyClass) -> usize {
        let depths = self.inner.depths.lock().unwrap();
        depths.get(graph).map_or(0, |c| c.per_class[class_index(class)])
    }

    /// Snapshot of every `(graph, class, depth)` seen so far (including
    /// zeros — Prometheus gauges should not disappear when idle).
    pub fn snapshot(&self) -> Vec<(String, AccuracyClass, usize)> {
        let depths = self.inner.depths.lock().unwrap();
        let mut out = Vec::with_capacity(depths.len() * 4);
        for (graph, counts) in depths.iter() {
            for class in AccuracyClass::all() {
                out.push((graph.clone(), class, counts.per_class[class_index(class)]));
            }
        }
        out
    }
}

/// Rejection: the caller should answer 429 with this `Retry-After` hint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shed {
    /// Suggested client back-off (milliseconds).
    pub retry_after_ms: u64,
}

/// RAII admission slot: dropping it releases the in-flight count.
#[derive(Debug)]
pub struct AdmitGuard {
    inner: Arc<Inner>,
    graph: String,
    idx: usize,
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        let mut depths = self.inner.depths.lock().unwrap();
        if let Some(counts) = depths.get_mut(&self.graph) {
            counts.per_class[self.idx] = counts.per_class[self.idx].saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(queue_cap: usize) -> ServeConfig {
        ServeConfig { queue_cap, ..Default::default() }
    }

    #[test]
    fn admits_until_class_threshold() {
        // cap 8, fast threshold ceil(8 × 0.5) = 4
        let adm = Admission::new(&cfg(8));
        assert_eq!(adm.limit(AccuracyClass::Fast), 4);
        assert_eq!(adm.limit(AccuracyClass::Balanced), 6);
        assert_eq!(adm.limit(AccuracyClass::Exact), 8);
        assert_eq!(adm.limit(AccuracyClass::Static), 8);

        let mut guards = Vec::new();
        for _ in 0..4 {
            guards.push(adm.try_admit("g", AccuracyClass::Fast).expect("below threshold"));
        }
        // fast is now at its threshold: the next fast sheds...
        let shed = adm.try_admit("g", AccuracyClass::Fast).unwrap_err();
        assert_eq!(shed.retry_after_ms, adm.retry_after_ms());
        // ...while balanced and exact still admit
        guards.push(adm.try_admit("g", AccuracyClass::Balanced).expect("balanced survives"));
        guards.push(adm.try_admit("g", AccuracyClass::Balanced).expect("balanced survives"));
        assert!(adm.try_admit("g", AccuracyClass::Balanced).is_err(), "balanced at 6");
        guards.push(adm.try_admit("g", AccuracyClass::Exact).expect("exact survives"));
        guards.push(adm.try_admit("g", AccuracyClass::Exact).expect("exact survives"));
        assert!(adm.try_admit("g", AccuracyClass::Exact).is_err(), "queue truly full");

        assert_eq!(adm.depth("g", AccuracyClass::Fast), 4);
        assert_eq!(adm.depth("g", AccuracyClass::Balanced), 2);
        drop(guards);
        assert_eq!(adm.depth("g", AccuracyClass::Fast), 0, "guards release on drop");
        adm.try_admit("g", AccuracyClass::Fast).expect("slots recycled");
    }

    #[test]
    fn graphs_have_independent_budgets() {
        let adm = Admission::new(&cfg(1));
        let _a = adm.try_admit("a", AccuracyClass::Exact).unwrap();
        assert!(adm.try_admit("a", AccuracyClass::Exact).is_err(), "a is full");
        let _b = adm.try_admit("b", AccuracyClass::Exact).expect("b has its own budget");
    }

    #[test]
    fn snapshot_lists_all_classes_of_seen_graphs() {
        let adm = Admission::new(&cfg(4));
        let _g = adm.try_admit("g", AccuracyClass::Balanced).unwrap();
        let snap = adm.snapshot();
        assert_eq!(snap.len(), 4, "all four classes, including zeros");
        let balanced = snap
            .iter()
            .find(|(_, c, _)| *c == AccuracyClass::Balanced)
            .map(|(_, _, d)| *d);
        assert_eq!(balanced, Some(1));
        assert!(snap.iter().all(|(g, _, _)| g == "g"));
    }

    #[test]
    fn tiny_caps_always_admit_at_least_one() {
        // ceil(1 × 0.5) = 1: even the lowest class can use an empty queue
        let adm = Admission::new(&cfg(1));
        let g = adm.try_admit("g", AccuracyClass::Fast).expect("empty queue admits");
        assert!(adm.try_admit("g", AccuracyClass::Fast).is_err());
        drop(g);
    }
}
