//! Prometheus metrics for the HTTP front door: counters, gauges and
//! fixed-bucket latency histograms rendered in text exposition format
//! 0.0.4, plus a tiny exposition parser used by tests and the serving
//! bench to assert `/metrics` stays well-formed.
//!
//! Metric taxonomy (documented in DESIGN.md §8):
//!
//! | name | type | labels |
//! |---|---|---|
//! | `ppr_http_requests_total` | counter | `graph`, `class`, `code` |
//! | `ppr_http_shed_total` | counter | `graph`, `class` |
//! | `ppr_http_deadline_misses_total` | counter | `graph`, `class` |
//! | `ppr_ladder_escalations_total` | counter | `graph`, `class` |
//! | `ppr_http_queue_depth` | gauge | `graph`, `class` |
//! | `ppr_http_request_duration_seconds` | histogram | `class` |
//! | `ppr_workers_live` / `ppr_workers_total` | gauge | — |
//! | `ppr_stuck_batch_age_seconds` | gauge | — |
//! | `ppr_worker_respawns_total` | counter | — |
//! | `ppr_engine_panics_total` | counter | — |
//! | `ppr_degraded_responses_total` | counter | — |
//! | `ppr_pool_caught_panics_total` | counter | — |
//! | `ppr_breaker_state` | gauge | `graph`, `class`, `backend` (0/1/2) |
//! | `ppr_breaker_open_total` / `ppr_breaker_cycles_total` | counter | — |
//! | `ppr_registry_resident_ram` | gauge | — |
//! | `ppr_registry_resident_disk` | gauge | — |
//! | `ppr_registry_capacity` | gauge | — |
//! | `ppr_registry_artifact_hits_total` | counter | `graph` |
//! | `ppr_backend_available` | gauge | `backend` |
//! | `ppr_dispatch_policy` | gauge | `policy` (1 = active) |
//! | `ppr_dispatch_routed_total` | counter | `backend` |
//! | `ppr_dispatch_stolen_total` | counter | `backend` |
//! | `ppr_backend_workers` | gauge | `backend` |
//! | `ppr_backend_queue_depth` | gauge | `backend` |
//!
//! The dispatch families (DESIGN.md §12) appear only on servers started
//! under heterogeneous dispatch; `ppr_backend_available` is always
//! emitted, covering every known backend with a 0/1 gauge.
//!
//! The serving-core health families (workers, breaker, degradation —
//! DESIGN.md §10) are sampled by the caller at scrape time and passed
//! into [`HttpMetrics::render_with`] as a [`CoreHealth`]; the registry
//! itself only accumulates HTTP-level counters.
//!
//! The histogram uses fixed log-spaced buckets (powers of two from 1 ms
//! to ~8 s), so scrapes are mergeable across processes and time — no
//! adaptive bucketing.
//!
//! Like `coordinator::stats`, all state sits behind one mutex so a scrape
//! is a consistent point-in-time view (a shed can never be visible before
//! the request that caused it).

use super::breaker::BreakerState;
use crate::coordinator::{DispatchStats, EngineKind};
use crate::fixed::AccuracyClass;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Histogram bucket upper bounds (seconds): 1 ms · 2^i.
pub const LATENCY_BUCKETS_S: [f64; 14] = [
    0.001, 0.002, 0.004, 0.008, 0.016, 0.032, 0.064, 0.128, 0.256, 0.512, 1.024, 2.048, 4.096,
    8.192,
];

#[derive(Debug, Default)]
struct Hist {
    /// Count per bucket of [`LATENCY_BUCKETS_S`] (non-cumulative; the
    /// renderer accumulates into Prometheus' cumulative `le` form).
    buckets: [u64; LATENCY_BUCKETS_S.len()],
    /// Observations above the last bound.
    overflow: u64,
    sum: f64,
    count: u64,
}

impl Hist {
    fn observe(&mut self, secs: f64) {
        match LATENCY_BUCKETS_S.iter().position(|&b| secs <= b) {
            Some(i) => self.buckets[i] += 1,
            None => self.overflow += 1,
        }
        self.sum += secs;
        self.count += 1;
    }
}

#[derive(Debug, Default)]
struct Inner {
    /// `(graph, class, code) → count`.
    requests: BTreeMap<(String, &'static str, u16), u64>,
    /// `(graph, class) → count`.
    shed: BTreeMap<(String, &'static str), u64>,
    misses: BTreeMap<(String, &'static str), u64>,
    escalations: BTreeMap<(String, &'static str), u64>,
    latency: BTreeMap<&'static str, Hist>,
}

/// Point-in-time serving-core health, sampled by the scrape handler and
/// rendered as gauge/counter families alongside the HTTP metrics.
#[derive(Debug, Clone, Default)]
pub struct CoreHealth {
    /// Workers currently alive.
    pub workers_live: u64,
    /// Configured worker count.
    pub workers_total: u64,
    /// Watchdog respawns so far.
    pub worker_respawns: u64,
    /// Age of the oldest in-flight batch (0 when idle).
    pub stuck_batch_age_seconds: f64,
    /// Engine panics contained by the batch boundary.
    pub engine_panics: u64,
    /// Responses produced by the degradation policy.
    pub degraded_responses: u64,
    /// Panics swallowed by detached runtime-pool tasks.
    pub pool_caught_panics: u64,
    /// Current breaker state per `(graph, class, backend)`.
    pub breaker_states: Vec<(Arc<str>, AccuracyClass, EngineKind, BreakerState)>,
    /// Closed → open breaker trips.
    pub breaker_opens: u64,
    /// Completed open → half-open → closed recovery cycles.
    pub breaker_cycles: u64,
    /// Fully-prepared registry entries resident in RAM (DESIGN.md §11).
    pub registry_resident_ram: u64,
    /// Registry entries demoted to disk-resident schedule artifacts.
    pub registry_resident_disk: u64,
    /// RAM residency cap of the registry.
    pub registry_capacity: u64,
    /// Artifact cold-start hits per graph (promotions and cross-process
    /// cold starts served from an on-disk artifact instead of a re-prep).
    pub artifact_hits: Vec<(Arc<str>, u64)>,
    /// Backends this server stood up (lanes that survived the probe
    /// build), rendered as the `ppr_backend_available` 0/1 gauge.
    pub backends: Vec<EngineKind>,
    /// Dispatcher routing counters; `None` on statically-routed servers
    /// (the dispatch families are then omitted entirely).
    pub dispatch: Option<DispatchStats>,
}

/// Thread-safe metric registry of the front door.
#[derive(Debug, Default)]
pub struct HttpMetrics {
    inner: Mutex<Inner>,
}

impl HttpMetrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished HTTP exchange: response `code`, end-to-end
    /// handler latency, and how many ladder rung escalations the answer
    /// took (0 for non-200s). 429s also count as sheds, 504s as deadline
    /// misses. `label` is [`AccuracyClass::label`] — or `"unknown"` for
    /// requests rejected before their class string parsed.
    pub fn record(
        &self,
        graph: &str,
        label: &'static str,
        code: u16,
        latency_secs: f64,
        escalations: u64,
    ) {
        let mut inner = self.inner.lock().unwrap();
        *inner.requests.entry((graph.to_string(), label, code)).or_insert(0) += 1;
        if code == 429 {
            *inner.shed.entry((graph.to_string(), label)).or_insert(0) += 1;
        }
        if code == 504 {
            *inner.misses.entry((graph.to_string(), label)).or_insert(0) += 1;
        }
        if escalations > 0 {
            *inner.escalations.entry((graph.to_string(), label)).or_insert(0) += escalations;
        }
        inner.latency.entry(label).or_default().observe(latency_secs);
    }

    /// Total requests recorded (all labels).
    pub fn total_requests(&self) -> u64 {
        self.inner.lock().unwrap().requests.values().sum()
    }

    /// Render the registry as Prometheus text exposition. `queue_depths`
    /// supplies the current admission-queue gauge values (sampled by the
    /// caller at scrape time — gauges are not accumulated here).
    pub fn render(&self, queue_depths: &[(String, AccuracyClass, usize)]) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::with_capacity(4096);

        out.push_str("# HELP ppr_http_requests_total HTTP requests by graph, class and status code.\n");
        out.push_str("# TYPE ppr_http_requests_total counter\n");
        for ((graph, class, code), n) in &inner.requests {
            out.push_str(&format!(
                "ppr_http_requests_total{{graph=\"{graph}\",class=\"{class}\",code=\"{code}\"}} {n}\n"
            ));
        }

        out.push_str("# HELP ppr_http_shed_total Requests rejected by admission control (429).\n");
        out.push_str("# TYPE ppr_http_shed_total counter\n");
        for ((graph, class), n) in &inner.shed {
            out.push_str(&format!(
                "ppr_http_shed_total{{graph=\"{graph}\",class=\"{class}\"}} {n}\n"
            ));
        }

        out.push_str("# HELP ppr_http_deadline_misses_total Requests that exceeded their deadline (504).\n");
        out.push_str("# TYPE ppr_http_deadline_misses_total counter\n");
        for ((graph, class), n) in &inner.misses {
            out.push_str(&format!(
                "ppr_http_deadline_misses_total{{graph=\"{graph}\",class=\"{class}\"}} {n}\n"
            ));
        }

        out.push_str("# HELP ppr_ladder_escalations_total Precision-ladder rung escalations taken by served queries.\n");
        out.push_str("# TYPE ppr_ladder_escalations_total counter\n");
        for ((graph, class), n) in &inner.escalations {
            out.push_str(&format!(
                "ppr_ladder_escalations_total{{graph=\"{graph}\",class=\"{class}\"}} {n}\n"
            ));
        }

        out.push_str("# HELP ppr_http_queue_depth Admitted in-flight requests per graph and class.\n");
        out.push_str("# TYPE ppr_http_queue_depth gauge\n");
        for (graph, class, depth) in queue_depths {
            out.push_str(&format!(
                "ppr_http_queue_depth{{graph=\"{graph}\",class=\"{}\"}} {depth}\n",
                class.label()
            ));
        }

        out.push_str("# HELP ppr_http_request_duration_seconds End-to-end request latency.\n");
        out.push_str("# TYPE ppr_http_request_duration_seconds histogram\n");
        for (class, hist) in &inner.latency {
            let mut cumulative = 0u64;
            for (i, &bound) in LATENCY_BUCKETS_S.iter().enumerate() {
                cumulative += hist.buckets[i];
                out.push_str(&format!(
                    "ppr_http_request_duration_seconds_bucket{{class=\"{class}\",le=\"{bound}\"}} {cumulative}\n"
                ));
            }
            cumulative += hist.overflow;
            out.push_str(&format!(
                "ppr_http_request_duration_seconds_bucket{{class=\"{class}\",le=\"+Inf\"}} {cumulative}\n"
            ));
            out.push_str(&format!(
                "ppr_http_request_duration_seconds_sum{{class=\"{class}\"}} {}\n",
                hist.sum
            ));
            out.push_str(&format!(
                "ppr_http_request_duration_seconds_count{{class=\"{class}\"}} {}\n",
                hist.count
            ));
        }
        out
    }

    /// [`Self::render`] plus the serving-core health families (worker
    /// liveness, stuck-batch age, panic/degradation counters, breaker
    /// states — DESIGN.md §10) sampled into `core` at scrape time.
    pub fn render_with(
        &self,
        queue_depths: &[(String, AccuracyClass, usize)],
        core: &CoreHealth,
    ) -> String {
        let mut out = self.render(queue_depths);

        out.push_str("# HELP ppr_workers_live Worker threads currently alive.\n");
        out.push_str("# TYPE ppr_workers_live gauge\n");
        out.push_str(&format!("ppr_workers_live {}\n", core.workers_live));

        out.push_str("# HELP ppr_workers_total Configured worker thread count.\n");
        out.push_str("# TYPE ppr_workers_total gauge\n");
        out.push_str(&format!("ppr_workers_total {}\n", core.workers_total));

        out.push_str("# HELP ppr_stuck_batch_age_seconds Age of the oldest in-flight batch.\n");
        out.push_str("# TYPE ppr_stuck_batch_age_seconds gauge\n");
        out.push_str(&format!("ppr_stuck_batch_age_seconds {}\n", core.stuck_batch_age_seconds));

        out.push_str("# HELP ppr_worker_respawns_total Dead workers respawned by the watchdog.\n");
        out.push_str("# TYPE ppr_worker_respawns_total counter\n");
        out.push_str(&format!("ppr_worker_respawns_total {}\n", core.worker_respawns));

        out.push_str("# HELP ppr_engine_panics_total Engine panics contained at the batch boundary.\n");
        out.push_str("# TYPE ppr_engine_panics_total counter\n");
        out.push_str(&format!("ppr_engine_panics_total {}\n", core.engine_panics));

        out.push_str("# HELP ppr_degraded_responses_total Responses served by the degradation policy.\n");
        out.push_str("# TYPE ppr_degraded_responses_total counter\n");
        out.push_str(&format!("ppr_degraded_responses_total {}\n", core.degraded_responses));

        out.push_str("# HELP ppr_pool_caught_panics_total Panics swallowed by detached runtime-pool tasks.\n");
        out.push_str("# TYPE ppr_pool_caught_panics_total counter\n");
        out.push_str(&format!("ppr_pool_caught_panics_total {}\n", core.pool_caught_panics));

        out.push_str("# HELP ppr_breaker_state Circuit breaker state (0=closed, 1=open, 2=half-open).\n");
        out.push_str("# TYPE ppr_breaker_state gauge\n");
        for (graph, class, backend, st) in &core.breaker_states {
            out.push_str(&format!(
                "ppr_breaker_state{{graph=\"{graph}\",class=\"{}\",backend=\"{}\"}} {}\n",
                class.label(),
                backend.label(),
                st.as_gauge()
            ));
        }

        out.push_str("# HELP ppr_breaker_open_total Closed-to-open breaker trips.\n");
        out.push_str("# TYPE ppr_breaker_open_total counter\n");
        out.push_str(&format!("ppr_breaker_open_total {}\n", core.breaker_opens));

        out.push_str("# HELP ppr_breaker_cycles_total Completed open-half-open-closed recovery cycles.\n");
        out.push_str("# TYPE ppr_breaker_cycles_total counter\n");
        out.push_str(&format!("ppr_breaker_cycles_total {}\n", core.breaker_cycles));

        out.push_str("# HELP ppr_registry_resident_ram Fully-prepared registry entries resident in RAM.\n");
        out.push_str("# TYPE ppr_registry_resident_ram gauge\n");
        out.push_str(&format!("ppr_registry_resident_ram {}\n", core.registry_resident_ram));

        out.push_str("# HELP ppr_registry_resident_disk Registry entries demoted to disk-resident schedule artifacts.\n");
        out.push_str("# TYPE ppr_registry_resident_disk gauge\n");
        out.push_str(&format!("ppr_registry_resident_disk {}\n", core.registry_resident_disk));

        out.push_str("# HELP ppr_registry_capacity RAM residency cap of the graph registry.\n");
        out.push_str("# TYPE ppr_registry_capacity gauge\n");
        out.push_str(&format!("ppr_registry_capacity {}\n", core.registry_capacity));

        out.push_str("# HELP ppr_registry_artifact_hits_total Cold starts served from an on-disk schedule artifact instead of a re-preparation.\n");
        out.push_str("# TYPE ppr_registry_artifact_hits_total counter\n");
        for (graph, n) in &core.artifact_hits {
            out.push_str(&format!(
                "ppr_registry_artifact_hits_total{{graph=\"{graph}\"}} {n}\n"
            ));
        }

        out.push_str("# HELP ppr_backend_available Whether the server stood this backend up (1) or not (0).\n");
        out.push_str("# TYPE ppr_backend_available gauge\n");
        for kind in EngineKind::all() {
            let up = u64::from(core.backends.contains(&kind));
            out.push_str(&format!("ppr_backend_available{{backend=\"{}\"}} {up}\n", kind.label()));
        }

        if let Some(d) = &core.dispatch {
            out.push_str("# HELP ppr_dispatch_policy Active dispatch policy (1 = the labeled policy).\n");
            out.push_str("# TYPE ppr_dispatch_policy gauge\n");
            out.push_str(&format!("ppr_dispatch_policy{{policy=\"{}\"}} 1\n", d.policy.label()));

            out.push_str("# HELP ppr_dispatch_routed_total Batches routed to each backend by the dispatcher.\n");
            out.push_str("# TYPE ppr_dispatch_routed_total counter\n");
            for b in &d.backends {
                out.push_str(&format!(
                    "ppr_dispatch_routed_total{{backend=\"{}\"}} {}\n",
                    b.kind.label(),
                    b.routed
                ));
            }

            out.push_str("# HELP ppr_dispatch_stolen_total Batches each backend stole from another backend's queue.\n");
            out.push_str("# TYPE ppr_dispatch_stolen_total counter\n");
            for b in &d.backends {
                out.push_str(&format!(
                    "ppr_dispatch_stolen_total{{backend=\"{}\"}} {}\n",
                    b.kind.label(),
                    b.stolen
                ));
            }

            out.push_str("# HELP ppr_backend_workers Workers draining each backend's queue.\n");
            out.push_str("# TYPE ppr_backend_workers gauge\n");
            for b in &d.backends {
                out.push_str(&format!(
                    "ppr_backend_workers{{backend=\"{}\"}} {}\n",
                    b.kind.label(),
                    b.workers
                ));
            }

            out.push_str("# HELP ppr_backend_queue_depth Batches queued per backend lane.\n");
            out.push_str("# TYPE ppr_backend_queue_depth gauge\n");
            for b in &d.backends {
                out.push_str(&format!(
                    "ppr_backend_queue_depth{{backend=\"{}\"}} {}\n",
                    b.kind.label(),
                    b.depth
                ));
            }
        }

        out
    }
}

/// Validate a Prometheus text exposition document: every non-comment line
/// must be `name{labels} value` (or `name value`) with a legal metric
/// name, well-formed label pairs and a parseable value; every sample's
/// family must have a preceding `# TYPE`. Returns the sample count.
/// This is the checker CI runs against the live `/metrics` endpoint.
pub fn validate_exposition(text: &str) -> Result<usize> {
    let mut typed: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let n = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            if !is_metric_name(name) {
                bail!("line {n}: bad metric name in TYPE: {name:?}");
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                bail!("line {n}: bad metric type {kind:?}");
            }
            typed.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or free-form comment
        }

        // sample line: name[{labels}] value
        let (name_labels, value) = match line.rsplit_once(' ') {
            Some(pair) => pair,
            None => bail!("line {n}: expected 'name value', got {line:?}"),
        };
        if value.parse::<f64>().is_err() && !matches!(value, "+Inf" | "-Inf" | "NaN") {
            bail!("line {n}: bad sample value {value:?}");
        }
        let name = match name_labels.split_once('{') {
            Some((name, labels)) => {
                let labels = labels
                    .strip_suffix('}')
                    .ok_or_else(|| anyhow::anyhow!("line {n}: unterminated label set"))?;
                for pair in labels.split(',').filter(|s| !s.is_empty()) {
                    let (k, v) = pair
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("line {n}: bad label pair {pair:?}"))?;
                    if !is_label_name(k) {
                        bail!("line {n}: bad label name {k:?}");
                    }
                    if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
                        bail!("line {n}: unquoted label value {v:?}");
                    }
                }
                name
            }
            None => name_labels,
        };
        if !is_metric_name(name) {
            bail!("line {n}: bad metric name {name:?}");
        }
        // histogram series carry the family name plus a suffix
        let family_known = typed.iter().any(|t| {
            name == t
                || name == format!("{t}_bucket")
                || name == format!("{t}_sum")
                || name == format!("{t}_count")
        });
        if !family_known {
            bail!("line {n}: sample {name:?} has no preceding # TYPE");
        }
        samples += 1;
    }
    Ok(samples)
}

fn is_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn is_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_valid_exposition() {
        let m = HttpMetrics::new();
        m.record("ws", AccuracyClass::Fast.label(), 200, 0.004, 1);
        m.record("ws", AccuracyClass::Fast.label(), 429, 0.0001, 0);
        m.record("ws", AccuracyClass::Exact.label(), 504, 0.3, 0);
        m.record("er", AccuracyClass::Balanced.label(), 200, 12.0, 2);
        let depths = vec![
            ("ws".to_string(), AccuracyClass::Fast, 3usize),
            ("er".to_string(), AccuracyClass::Exact, 0usize),
        ];
        let text = m.render(&depths);
        let samples = validate_exposition(&text).expect("render must validate");
        assert!(samples > 10, "{samples} samples:\n{text}");
        assert!(text.contains("ppr_http_requests_total{graph=\"ws\",class=\"fast\",code=\"200\"} 1\n"));
        assert!(text.contains("ppr_http_shed_total{graph=\"ws\",class=\"fast\"} 1\n"));
        assert!(text.contains("ppr_http_deadline_misses_total{graph=\"ws\",class=\"exact\"} 1\n"));
        assert!(text.contains("ppr_ladder_escalations_total{graph=\"er\",class=\"balanced\"} 2\n"));
        assert!(text.contains("ppr_http_queue_depth{graph=\"ws\",class=\"fast\"} 3\n"));
        assert_eq!(m.total_requests(), 4);
    }

    #[test]
    fn render_with_emits_core_health_families() {
        let m = HttpMetrics::new();
        m.record("ws", AccuracyClass::Exact.label(), 200, 0.01, 0);
        let core = CoreHealth {
            workers_live: 3,
            workers_total: 4,
            worker_respawns: 2,
            stuck_batch_age_seconds: 0.5,
            engine_panics: 7,
            degraded_responses: 5,
            pool_caught_panics: 1,
            breaker_states: vec![
                (Arc::from("ws"), AccuracyClass::Exact, EngineKind::Native, BreakerState::Open),
                (
                    Arc::from("er"),
                    AccuracyClass::Fast,
                    EngineKind::CpuBaseline,
                    BreakerState::Closed,
                ),
            ],
            breaker_opens: 3,
            breaker_cycles: 1,
            registry_resident_ram: 2,
            registry_resident_disk: 4,
            registry_capacity: 2,
            artifact_hits: vec![(Arc::from("ws"), 6), (Arc::from("er"), 0)],
            backends: vec![EngineKind::Native, EngineKind::CpuBaseline],
            dispatch: None,
        };
        let text = m.render_with(&[], &core);
        validate_exposition(&text).expect("core families must validate");
        assert!(text.contains("ppr_workers_live 3\n"), "{text}");
        assert!(text.contains("ppr_workers_total 4\n"));
        assert!(text.contains("ppr_worker_respawns_total 2\n"));
        assert!(text.contains("ppr_stuck_batch_age_seconds 0.5\n"));
        assert!(text.contains("ppr_engine_panics_total 7\n"));
        assert!(text.contains("ppr_degraded_responses_total 5\n"));
        assert!(text.contains("ppr_pool_caught_panics_total 1\n"));
        assert!(text.contains("ppr_breaker_state{graph=\"ws\",class=\"exact\",backend=\"native\"} 1\n"));
        assert!(
            text.contains("ppr_breaker_state{graph=\"er\",class=\"fast\",backend=\"cpu-baseline\"} 0\n")
        );
        assert!(text.contains("ppr_breaker_open_total 3\n"));
        assert!(text.contains("ppr_breaker_cycles_total 1\n"));
        assert!(text.contains("ppr_registry_resident_ram 2\n"));
        assert!(text.contains("ppr_registry_resident_disk 4\n"));
        assert!(text.contains("ppr_registry_capacity 2\n"));
        assert!(text.contains("ppr_registry_artifact_hits_total{graph=\"ws\"} 6\n"));
        assert!(text.contains("ppr_registry_artifact_hits_total{graph=\"er\"} 0\n"));
        // availability covers every known backend, 0/1
        assert!(text.contains("ppr_backend_available{backend=\"native\"} 1\n"));
        assert!(text.contains("ppr_backend_available{backend=\"cpu-baseline\"} 1\n"));
        assert!(text.contains("ppr_backend_available{backend=\"pjrt\"} 0\n"));
        // static server: no dispatch families at all
        assert!(!text.contains("ppr_dispatch_policy"), "{text}");
    }

    #[test]
    fn render_with_emits_dispatch_families() {
        use crate::coordinator::dispatch::BackendStat;
        use crate::coordinator::DispatchPolicy;
        let m = HttpMetrics::new();
        let core = CoreHealth {
            backends: vec![EngineKind::Native, EngineKind::CpuBaseline],
            dispatch: Some(DispatchStats {
                policy: DispatchPolicy::Cost,
                backends: vec![
                    BackendStat {
                        kind: EngineKind::Native,
                        workers: 2,
                        routed: 9,
                        stolen: 1,
                        depth: 3,
                    },
                    BackendStat {
                        kind: EngineKind::CpuBaseline,
                        workers: 2,
                        routed: 4,
                        stolen: 2,
                        depth: 0,
                    },
                ],
            }),
            ..Default::default()
        };
        let text = m.render_with(&[], &core);
        validate_exposition(&text).expect("dispatch families must validate");
        assert!(text.contains("ppr_dispatch_policy{policy=\"cost\"} 1\n"), "{text}");
        assert!(text.contains("ppr_dispatch_routed_total{backend=\"native\"} 9\n"));
        assert!(text.contains("ppr_dispatch_routed_total{backend=\"cpu-baseline\"} 4\n"));
        assert!(text.contains("ppr_dispatch_stolen_total{backend=\"native\"} 1\n"));
        assert!(text.contains("ppr_dispatch_stolen_total{backend=\"cpu-baseline\"} 2\n"));
        assert!(text.contains("ppr_backend_workers{backend=\"native\"} 2\n"));
        assert!(text.contains("ppr_backend_queue_depth{backend=\"native\"} 3\n"));
        assert!(text.contains("ppr_backend_queue_depth{backend=\"cpu-baseline\"} 0\n"));
    }

    #[test]
    fn histogram_buckets_cumulative_and_bounded() {
        let m = HttpMetrics::new();
        m.record("g", "static", 200, 0.0005, 0); // below first bound
        m.record("g", "static", 200, 0.005, 0);
        m.record("g", "static", 200, 100.0, 0); // above last bound
        let text = m.render(&[]);
        assert!(text.contains("le=\"0.001\"} 1\n"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("ppr_http_request_duration_seconds_count{class=\"static\"} 3\n"));
        // cumulative counts never decrease across bounds
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{class=\"static\"")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        for bad in [
            "no_type_metric 1\n",                       // sample without TYPE
            "# TYPE m counter\nm{x=1} 2\n",             // unquoted label value
            "# TYPE m counter\nm{x=\"1\"} abc\n",       // bad value
            "# TYPE m bogus\n",                         // bad type
            "# TYPE m counter\nm{x=\"1\" 2\n",          // unterminated labels
            "# TYPE 1bad counter\n1bad 2\n",            // bad metric name
            "# TYPE m counter\nnothing-here\n",         // no value separator
        ] {
            assert!(validate_exposition(bad).is_err(), "{bad:?} should fail");
        }
        let good = "# HELP m help text\n# TYPE m gauge\nm 1\nm{a=\"b\"} 2.5\n";
        assert_eq!(validate_exposition(good).unwrap(), 2);
    }
}
