//! Closed-loop serving benchmark client: open-loop Poisson arrivals over
//! real sockets.
//!
//! "Open-loop" is the part that matters: arrival times are drawn up front
//! from an exponential inter-arrival distribution at the offered rate,
//! and every request's latency is measured **from its scheduled arrival**,
//! not from when a client thread got around to sending it. A closed-loop
//! client (send, wait, send) self-throttles under overload and hides
//! queueing delay — exactly the regime the admission controller exists
//! for — so the schedule, not the server, paces the experiment
//! (coordinated-omission-free measurement).
//!
//! Determinism: the schedule (arrival times, class assignment,
//! personalization vertices) is derived from a seeded [`Xoshiro256`], so
//! two runs against the same server offer byte-identical request
//! sequences. Client threads race for schedule slots at run time, which
//! only affects *which thread* carries a request, never what is sent.

use super::http::{format_request, roundtrip};
use crate::fixed::AccuracyClass;
use crate::util::Xoshiro256;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What to offer the server.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Graph name in the request path.
    pub graph: String,
    /// `(class, weight)` mix; weights need not sum to 1.
    pub class_mix: Vec<(AccuracyClass, f64)>,
    /// Offered arrival rate (requests/second) across all classes.
    pub offered_rps: f64,
    /// Schedule length.
    pub duration: Duration,
    /// Concurrent client connections.
    pub clients: usize,
    /// `top_n` sent with every request.
    pub top_n: usize,
    /// Optional per-request deadline forwarded to the server.
    pub deadline_ms: Option<u64>,
    /// Personalization vertices are drawn uniformly from `[0, max_vertex)`.
    pub max_vertex: u64,
    /// Schedule seed.
    pub seed: u64,
}

/// One scheduled arrival.
#[derive(Debug, Clone, Copy)]
struct Event {
    at: Duration,
    class_idx: usize,
    vertex: u64,
}

/// Outcome tallies and latencies for one accuracy class.
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    /// Requests sent (admitted to the wire, any outcome).
    pub sent: u64,
    /// 200 responses.
    pub ok: u64,
    /// 429 responses (admission shed).
    pub shed: u64,
    /// 504 responses (deadline misses).
    pub deadline_miss: u64,
    /// Any other HTTP status.
    pub error: u64,
    /// Latency of every answered request, milliseconds, measured from the
    /// scheduled arrival. Sorted by [`LoadReport::finish`].
    pub latencies_ms: Vec<f64>,
}

impl ClassStats {
    /// Latency percentile in milliseconds (`p` in `[0, 100]`); `None`
    /// without samples. Requires sorted latencies (see
    /// [`LoadReport::finish`]).
    pub fn percentile_ms(&self, p: f64) -> Option<f64> {
        percentile_sorted(&self.latencies_ms, p)
    }

    /// Fraction of sent requests that were shed.
    pub fn shed_rate(&self) -> f64 {
        rate(self.shed, self.sent)
    }

    /// Fraction of sent requests that missed their deadline.
    pub fn deadline_miss_rate(&self) -> f64 {
        rate(self.deadline_miss, self.sent)
    }
}

fn rate(part: u64, whole: u64) -> f64 {
    if whole == 0 {
        0.0
    } else {
        part as f64 / whole as f64
    }
}

/// Nearest-rank percentile over an ascending slice.
fn percentile_sorted(sorted: &[f64], p: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

/// The result of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The configured arrival rate.
    pub offered_rps: f64,
    /// Successful (200) responses per wall-clock second.
    pub achieved_rps: f64,
    /// Wall-clock time from first scheduled arrival to last response.
    pub wall_secs: f64,
    /// Requests that got no HTTP response at all (transport failure).
    /// The acceptance gate: a correct front door never loses a request —
    /// every arrival gets 200/202/4xx/5xx, so this must be zero.
    pub lost: u64,
    /// Per-class outcome tallies, in [`AccuracyClass::all`] order (classes
    /// outside the mix are present with zero counts).
    pub per_class: Vec<(AccuracyClass, ClassStats)>,
}

impl LoadReport {
    /// Total requests sent across classes.
    pub fn total_sent(&self) -> u64 {
        self.per_class.iter().map(|(_, s)| s.sent).sum()
    }

    /// Stats for one class.
    pub fn class(&self, class: AccuracyClass) -> &ClassStats {
        &self.per_class.iter().find(|(c, _)| *c == class).expect("all classes present").1
    }

    fn finish(&mut self) {
        for (_, stats) in &mut self.per_class {
            stats.latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
    }
}

/// Draw the arrival schedule: exponential inter-arrival gaps at
/// `offered_rps`, class by weighted draw, vertex uniform.
fn build_schedule(spec: &LoadSpec) -> Vec<Event> {
    assert!(spec.offered_rps > 0.0, "offered_rps must be positive");
    assert!(!spec.class_mix.is_empty(), "class mix must not be empty");
    let total_weight: f64 = spec.class_mix.iter().map(|(_, w)| w).sum();
    assert!(total_weight > 0.0, "class weights must sum to a positive value");

    let mut rng = Xoshiro256::seeded(spec.seed);
    let mut events = Vec::new();
    let mut t = 0.0f64;
    loop {
        // u in [0,1) so 1−u in (0,1] and the log is finite
        let u = rng.next_f64();
        t += -(1.0 - u).ln() / spec.offered_rps;
        if t >= spec.duration.as_secs_f64() {
            break;
        }
        let mut pick = rng.next_f64() * total_weight;
        let mut class_idx = spec.class_mix.len() - 1;
        for (i, (_, w)) in spec.class_mix.iter().enumerate() {
            if pick < *w {
                class_idx = i;
                break;
            }
            pick -= w;
        }
        let vertex = (rng.next_f64() * spec.max_vertex as f64) as u64 % spec.max_vertex.max(1);
        events.push(Event { at: Duration::from_secs_f64(t), class_idx, vertex });
    }
    events
}

/// Per-thread tally merged into the report after the join.
#[derive(Default)]
struct ThreadTally {
    /// `(class_idx, status, latency_ms)` per answered request.
    outcomes: Vec<(usize, u16, f64)>,
    lost: u64,
}

/// Drive `spec` against a front door at `addr` and collect the report.
/// Blocks for roughly `spec.duration` plus the drain tail.
pub fn run(addr: SocketAddr, spec: &LoadSpec) -> LoadReport {
    let events = Arc::new(build_schedule(spec));
    let next = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    let clients = spec.clients.max(1);

    let tallies: Vec<ThreadTally> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let events = events.clone();
                let next = next.clone();
                scope.spawn(move || client_loop(addr, spec, &events, &next, start))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    let wall_secs = start.elapsed().as_secs_f64();
    let mut per_class: Vec<(AccuracyClass, ClassStats)> =
        AccuracyClass::all().into_iter().map(|c| (c, ClassStats::default())).collect();
    let mut lost = 0u64;
    for tally in tallies {
        lost += tally.lost;
        for (class_idx, status, latency_ms) in tally.outcomes {
            let class = spec.class_mix[class_idx].0;
            let stats =
                &mut per_class.iter_mut().find(|(c, _)| *c == class).expect("known class").1;
            stats.sent += 1;
            match status {
                200 => stats.ok += 1,
                429 => stats.shed += 1,
                504 => stats.deadline_miss += 1,
                _ => stats.error += 1,
            }
            stats.latencies_ms.push(latency_ms);
        }
    }

    let ok_total: u64 = per_class.iter().map(|(_, s)| s.ok).sum();
    let mut report = LoadReport {
        offered_rps: spec.offered_rps,
        achieved_rps: if wall_secs > 0.0 { ok_total as f64 / wall_secs } else { 0.0 },
        wall_secs,
        lost,
        per_class,
    };
    report.finish();
    report
}

/// One client: a persistent keep-alive connection racing the shared
/// schedule cursor. A transport failure counts the request lost and
/// reconnects; a dead server drains the remaining slots as lost rather
/// than hanging the run.
fn client_loop(
    addr: SocketAddr,
    spec: &LoadSpec,
    events: &[Event],
    next: &AtomicUsize,
    start: Instant,
) -> ThreadTally {
    let mut tally = ThreadTally::default();
    let mut conn: Option<TcpStream> = None;
    let host = addr.to_string();

    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        let Some(event) = events.get(i) else {
            break;
        };
        let now = start.elapsed();
        if event.at > now {
            std::thread::sleep(event.at - now);
        }

        let (class, _) = spec.class_mix[event.class_idx];
        let body = request_body(spec, class, event.vertex);
        let path = format!("/v1/graphs/{}/query", spec.graph);
        let request = format_request("POST", &path, &host, Some(&body));

        if conn.is_none() {
            match TcpStream::connect(addr) {
                Ok(s) => conn = Some(s),
                Err(_) => {
                    tally.lost += 1;
                    continue;
                }
            }
        }
        let stream = conn.as_mut().expect("connection just established");
        match roundtrip(stream, &request) {
            Ok((status, _body)) => {
                let latency_ms = (start.elapsed() - event.at).as_secs_f64() * 1e3;
                tally.outcomes.push((event.class_idx, status, latency_ms));
            }
            Err(_) => {
                tally.lost += 1;
                conn = None; // reconnect on the next slot
            }
        }
    }
    tally
}

fn request_body(spec: &LoadSpec, class: AccuracyClass, vertex: u64) -> String {
    let mut body = format!(
        "{{\"vertex\":{vertex},\"top_n\":{},\"class\":\"{}\"",
        spec.top_n,
        class.label()
    );
    if let Some(ms) = spec.deadline_ms {
        body.push_str(&format!(",\"deadline_ms\":{ms}"));
    }
    body.push('}');
    body
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(rps: f64, secs: f64, seed: u64) -> LoadSpec {
        LoadSpec {
            graph: "ws".to_string(),
            class_mix: vec![
                (AccuracyClass::Fast, 2.0),
                (AccuracyClass::Balanced, 1.0),
                (AccuracyClass::Exact, 1.0),
            ],
            offered_rps: rps,
            duration: Duration::from_secs_f64(secs),
            clients: 4,
            top_n: 5,
            deadline_ms: None,
            max_vertex: 100,
            seed,
        }
    }

    #[test]
    fn schedule_is_deterministic_and_paced() {
        let s = spec(200.0, 2.0, 42);
        let a = build_schedule(&s);
        let b = build_schedule(&s);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.class_idx, y.class_idx);
            assert_eq!(x.vertex, y.vertex);
        }
        // ~rps × secs arrivals, generously bounded (Poisson variance)
        assert!(a.len() > 250 && a.len() < 550, "{}", a.len());
        // monotone schedule inside the window
        for w in a.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(a.last().unwrap().at < s.duration);
        assert!(a.iter().all(|e| e.vertex < 100));
    }

    #[test]
    fn different_seeds_differ() {
        let a = build_schedule(&spec(100.0, 1.0, 1));
        let b = build_schedule(&spec(100.0, 1.0, 2));
        assert!(
            a.len() != b.len() || a.iter().zip(&b).any(|(x, y)| x.at != y.at),
            "seeds must change the schedule"
        );
    }

    #[test]
    fn class_mix_respects_weights() {
        let events = build_schedule(&spec(2000.0, 2.0, 7));
        let fast = events.iter().filter(|e| e.class_idx == 0).count() as f64;
        let frac = fast / events.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "fast fraction {frac} far from weight 0.5");
    }

    #[test]
    fn percentiles_over_sorted_samples() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&xs, 0.0), Some(1.0));
        assert_eq!(percentile_sorted(&xs, 100.0), Some(100.0));
        let p50 = percentile_sorted(&xs, 50.0).unwrap();
        assert!((49.0..=51.0).contains(&p50), "{p50}");
        assert_eq!(percentile_sorted(&[], 50.0), None);
        let one = [7.5];
        assert_eq!(percentile_sorted(&one, 99.9), Some(7.5));
    }

    #[test]
    fn class_stats_rates() {
        let s = ClassStats { sent: 10, ok: 6, shed: 3, deadline_miss: 1, ..Default::default() };
        assert!((s.shed_rate() - 0.3).abs() < 1e-12);
        assert!((s.deadline_miss_rate() - 0.1).abs() < 1e-12);
        assert_eq!(ClassStats::default().shed_rate(), 0.0, "no sends, no rate");
    }
}
