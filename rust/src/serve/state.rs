//! Shared state of the HTTP front door: the serving core handles, the
//! admission controller, the metric registry, and the store of
//! asynchronous tickets awaiting `GET /v1/tickets/{id}` polls.

use super::admission::{Admission, AdmitGuard};
use super::breaker::{Admission as BreakerAdmission, BreakerConfig, CircuitBreaker};
use super::prom::HttpMetrics;
use crate::config::ServeConfig;
use crate::coordinator::registry::GraphRegistry;
use crate::coordinator::request::{PprResponse, ServeError};
use crate::coordinator::server::{Server, Ticket};
use crate::coordinator::EngineKind;
use crate::fixed::AccuracyClass;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything a connection handler needs, shared behind one `Arc`.
pub struct ServeState {
    /// The serving core (batching, engines, per-graph stats).
    pub server: Arc<Server>,
    /// The graph registry behind the core (listing, routing).
    pub registry: Arc<GraphRegistry>,
    /// The validated `[serve]` configuration.
    pub cfg: ServeConfig,
    /// Admission control (per-graph bounded queues, class shed order).
    pub admission: Admission,
    /// Prometheus counters/histograms.
    pub metrics: HttpMetrics,
    /// Async tickets awaiting polls.
    pub tickets: TicketStore,
    /// Per-`(graph, class)` circuit breakers (DESIGN.md §10).
    pub breaker: Arc<CircuitBreaker>,
}

impl ServeState {
    /// Assemble the shared state from the core handles and config.
    pub fn new(server: Arc<Server>, registry: Arc<GraphRegistry>, cfg: ServeConfig) -> Self {
        let admission = Admission::new(&cfg);
        let ttl = Duration::from_secs(cfg.ticket_ttl_secs);
        let breaker = Arc::new(CircuitBreaker::new(BreakerConfig::from_serve(&cfg)));
        Self {
            server,
            registry,
            cfg,
            admission,
            metrics: HttpMetrics::new(),
            tickets: TicketStore::new(ttl, breaker.clone()),
            breaker,
        }
    }
}

/// One stored async submission: the ticket, its admission slot (released
/// when the entry is removed), its breaker key and admission (possibly a
/// half-open probe slot to settle), and its creation time for TTL expiry.
struct Stored {
    ticket: Ticket,
    /// Held for the entry's lifetime; dropping it releases admission.
    _guard: AdmitGuard,
    /// Interned graph key, kept so the final poll (or TTL expiry) can
    /// still feed the `(graph, class, backend)` circuit breaker.
    graph: Arc<str>,
    class: AccuracyClass,
    /// The breaker admission this submission rode in on; a reserved probe
    /// slot is settled by the final poll or returned on TTL expiry.
    admission: BreakerAdmission,
    created: Instant,
}

/// Outcome of polling a stored ticket.
#[derive(Debug)]
pub enum PollOutcome {
    /// No such ticket (never existed, already consumed, or TTL-expired).
    NotFound,
    /// Still in flight.
    Pending,
    /// Finished: the entry has been removed from the store. Carries the
    /// entry's `(graph, class)`, the backend that served it, and its
    /// breaker admission so the caller can attribute the verdict —
    /// breaker outcome, metrics — even when the result is an error that
    /// names none of them.
    Done {
        /// Interned graph key of the consumed entry.
        graph: Arc<str>,
        /// Accuracy class the query ran under.
        class: AccuracyClass,
        /// The backend whose engine served the ticket, if any solve ran.
        backend: Option<EngineKind>,
        /// The breaker admission the submission rode in on.
        admission: BreakerAdmission,
        /// The final verdict of the async request.
        result: Result<PprResponse, ServeError>,
    },
}

/// Thread-safe store of submitted-but-unpolled tickets. Entries are
/// removed when their result is consumed or when they outlive the TTL
/// (purged on every insert/poll — no background sweeper thread). An
/// expired entry observed no outcome, so its breaker admission — possibly
/// a half-open probe slot — is released, never leaked.
pub struct TicketStore {
    entries: Mutex<HashMap<u64, Stored>>,
    ttl: Duration,
    breaker: Arc<CircuitBreaker>,
}

impl TicketStore {
    /// New store with the given entry TTL, feeding `breaker` when entries
    /// expire unobserved.
    pub fn new(ttl: Duration, breaker: Arc<CircuitBreaker>) -> Self {
        Self { entries: Mutex::new(HashMap::new()), ttl, breaker }
    }

    /// Drop entries past the TTL, returning each one's breaker admission
    /// (a ticket abandoned by its client says nothing about backend
    /// health — but its probe slot must not leak, or a half-open breaker
    /// could wedge at 503 with no recovery path).
    fn purge_expired(&self, entries: &mut HashMap<u64, Stored>) {
        let now = Instant::now();
        entries.retain(|_, s| {
            if now.duration_since(s.created) < self.ttl {
                return true;
            }
            self.breaker.release(&s.graph, s.class, s.admission);
            false
        });
    }

    /// Store a submitted ticket with its admission slot and breaker
    /// admission; returns the ticket id the client polls with.
    pub fn insert(&self, ticket: Ticket, guard: AdmitGuard, admission: BreakerAdmission) -> u64 {
        let id = ticket.id();
        let graph = ticket.graph_key().clone();
        let class = ticket.class();
        let mut entries = self.entries.lock().unwrap();
        self.purge_expired(&mut entries);
        entries.insert(
            id,
            Stored { ticket, _guard: guard, graph, class, admission, created: Instant::now() },
        );
        id
    }

    /// Poll a ticket by id. A finished ticket is consumed: its entry (and
    /// admission slot) is released and a second poll returns `NotFound`.
    pub fn poll(&self, id: u64) -> PollOutcome {
        let mut entries = self.entries.lock().unwrap();
        self.purge_expired(&mut entries);
        let Some(stored) = entries.get(&id) else {
            return PollOutcome::NotFound;
        };
        match stored.ticket.poll() {
            None => PollOutcome::Pending,
            Some(result) => {
                let stored = entries.remove(&id).expect("entry present");
                let backend = stored.ticket.served_by();
                PollOutcome::Done {
                    graph: stored.graph,
                    class: stored.class,
                    backend,
                    admission: stored.admission,
                    result,
                }
            }
        }
    }

    /// Live (unconsumed, unexpired) entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether the store holds no live entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::builder::EngineBuilder;
    use crate::fixed::{AccuracyClass, Precision};

    fn tiny_server() -> Server {
        let g = crate::graph::generators::watts_strogatz(64, 4, 0.2, 11);
        let cfg = RunConfig {
            precision: Precision::Fixed(26),
            kappa: 2,
            iterations: 3,
            batch_timeout_ms: 1,
            num_shards: 1,
            ..Default::default()
        };
        EngineBuilder::native().config(cfg).serve(&g, 1).expect("server starts")
    }

    fn serve_cfg() -> ServeConfig {
        ServeConfig { queue_cap: 4, ..Default::default() }
    }

    fn test_breaker() -> Arc<CircuitBreaker> {
        Arc::new(CircuitBreaker::new(BreakerConfig::default()))
    }

    #[test]
    fn ticket_store_poll_consumes_once() {
        let server = tiny_server();
        let adm = Admission::new(&serve_cfg());
        let store = TicketStore::new(Duration::from_secs(60), test_breaker());

        let guard = adm.try_admit("default", AccuracyClass::Static).unwrap();
        let id = store.insert(server.submit(5, 3), guard, BreakerAdmission::none());
        assert_eq!(store.len(), 1);
        assert_eq!(adm.depth("default", AccuracyClass::Static), 1);

        let deadline = Instant::now() + Duration::from_secs(10);
        let (resp, graph, class, backend) = loop {
            match store.poll(id) {
                PollOutcome::Pending => {
                    assert!(Instant::now() < deadline, "never resolved");
                    std::thread::sleep(Duration::from_millis(2));
                }
                PollOutcome::Done { graph, class, backend, result, .. } => {
                    break (result.expect("query succeeds"), graph, class, backend)
                }
                PollOutcome::NotFound => panic!("ticket vanished while pending"),
            }
        };
        assert_eq!(resp.vertex, 5);
        assert_eq!(resp.ranking.len(), 3);
        // the consumed entry hands back its breaker key alongside the
        // result, so even error verdicts stay attributable
        assert_eq!(graph.as_ref(), "default");
        assert_eq!(class, AccuracyClass::Static);
        assert_eq!(backend, Some(EngineKind::Native), "served ticket carries its backend");
        // consumed: the entry and its admission slot are gone
        assert!(matches!(store.poll(id), PollOutcome::NotFound));
        assert!(store.is_empty());
        assert_eq!(adm.depth("default", AccuracyClass::Static), 0);
        server.shutdown();
    }

    #[test]
    fn ticket_store_expires_stale_entries() {
        let server = tiny_server();
        let adm = Admission::new(&serve_cfg());
        let store = TicketStore::new(Duration::from_millis(30), test_breaker());
        let guard = adm.try_admit("default", AccuracyClass::Static).unwrap();
        let id = store.insert(server.submit(1, 2), guard, BreakerAdmission::none());
        std::thread::sleep(Duration::from_millis(50));
        // the TTL purge runs on poll: the entry is gone and its slot free
        assert!(matches!(store.poll(id), PollOutcome::NotFound));
        assert_eq!(adm.depth("default", AccuracyClass::Static), 0);
        server.shutdown();
    }

    #[test]
    fn expired_ticket_releases_half_open_probe_slot() {
        // regression: a ticket admitted as a half-open probe and then
        // abandoned by its client used to leak the probe slot — with the
        // whole budget leaked the breaker wedged at 503 forever
        let server = tiny_server();
        let adm = Admission::new(&serve_cfg());
        // open_for is deliberately much longer than the ticket TTL so the
        // leaked-slot reclaim backstop cannot mask a missing release: only
        // the TTL purge can free the slot inside this test's window
        let breaker = Arc::new(CircuitBreaker::new(BreakerConfig {
            window: 8,
            failure_rate: 0.5,
            min_samples: 4,
            open_for: Duration::from_millis(200),
            half_open_probes: 1,
        }));
        let store = TicketStore::new(Duration::from_millis(40), breaker.clone());
        let g: Arc<str> = Arc::from("default");
        let native = &[EngineKind::Native];
        for _ in 0..4 {
            breaker.record(
                &g,
                AccuracyClass::Static,
                Some(EngineKind::Native),
                BreakerAdmission::none(),
                true,
            );
        }
        std::thread::sleep(Duration::from_millis(210));
        // the single probe slot goes to an async submission…
        let admission = breaker.check(&g, AccuracyClass::Static, native).expect("probe admitted");
        assert!(breaker.check(&g, AccuracyClass::Static, native).is_err(), "budget spent");
        let guard = adm.try_admit("default", AccuracyClass::Static).unwrap();
        let id = store.insert(server.submit(1, 2), guard, admission);
        // …which its client never polls: the TTL purge must return the slot
        std::thread::sleep(Duration::from_millis(60));
        assert!(matches!(store.poll(id), PollOutcome::NotFound));
        assert!(
            breaker.check(&g, AccuracyClass::Static, native).is_ok(),
            "expired entry must release its probe slot"
        );
        server.shutdown();
    }

    #[test]
    fn unknown_ticket_is_not_found() {
        let store = TicketStore::new(Duration::from_secs(1), test_breaker());
        assert!(matches!(store.poll(424242), PollOutcome::NotFound));
    }
}
