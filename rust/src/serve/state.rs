//! Shared state of the HTTP front door: the serving core handles, the
//! admission controller, the metric registry, and the store of
//! asynchronous tickets awaiting `GET /v1/tickets/{id}` polls.

use super::admission::{Admission, AdmitGuard};
use super::breaker::{BreakerConfig, CircuitBreaker};
use super::prom::HttpMetrics;
use crate::config::ServeConfig;
use crate::coordinator::registry::GraphRegistry;
use crate::coordinator::request::{PprResponse, ServeError};
use crate::coordinator::server::{Server, Ticket};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything a connection handler needs, shared behind one `Arc`.
pub struct ServeState {
    /// The serving core (batching, engines, per-graph stats).
    pub server: Arc<Server>,
    /// The graph registry behind the core (listing, routing).
    pub registry: Arc<GraphRegistry>,
    /// The validated `[serve]` configuration.
    pub cfg: ServeConfig,
    /// Admission control (per-graph bounded queues, class shed order).
    pub admission: Admission,
    /// Prometheus counters/histograms.
    pub metrics: HttpMetrics,
    /// Async tickets awaiting polls.
    pub tickets: TicketStore,
    /// Per-`(graph, class)` circuit breakers (DESIGN.md §10).
    pub breaker: Arc<CircuitBreaker>,
}

impl ServeState {
    /// Assemble the shared state from the core handles and config.
    pub fn new(server: Arc<Server>, registry: Arc<GraphRegistry>, cfg: ServeConfig) -> Self {
        let admission = Admission::new(&cfg);
        let ttl = Duration::from_secs(cfg.ticket_ttl_secs);
        let breaker = Arc::new(CircuitBreaker::new(BreakerConfig::from_serve(&cfg)));
        Self {
            server,
            registry,
            cfg,
            admission,
            metrics: HttpMetrics::new(),
            tickets: TicketStore::new(ttl),
            breaker,
        }
    }
}

/// One stored async submission: the ticket, its admission slot (released
/// when the entry is removed), and its creation time for TTL expiry.
struct Stored {
    ticket: Ticket,
    /// Held for the entry's lifetime; dropping it releases admission.
    _guard: AdmitGuard,
    created: Instant,
}

/// Outcome of polling a stored ticket.
#[derive(Debug)]
pub enum PollOutcome {
    /// No such ticket (never existed, already consumed, or TTL-expired).
    NotFound,
    /// Still in flight.
    Pending,
    /// Finished: the entry has been removed from the store.
    Done(Result<PprResponse, ServeError>),
}

/// Thread-safe store of submitted-but-unpolled tickets. Entries are
/// removed when their result is consumed or when they outlive the TTL
/// (purged on every insert/poll — no background sweeper thread).
pub struct TicketStore {
    entries: Mutex<HashMap<u64, Stored>>,
    ttl: Duration,
}

impl TicketStore {
    /// New store with the given entry TTL.
    pub fn new(ttl: Duration) -> Self {
        Self { entries: Mutex::new(HashMap::new()), ttl }
    }

    /// Store a submitted ticket with its admission slot; returns the
    /// ticket id the client polls with.
    pub fn insert(&self, ticket: Ticket, guard: AdmitGuard) -> u64 {
        let id = ticket.id();
        let mut entries = self.entries.lock().unwrap();
        let now = Instant::now();
        entries.retain(|_, s| now.duration_since(s.created) < self.ttl);
        entries.insert(id, Stored { ticket, _guard: guard, created: now });
        id
    }

    /// Poll a ticket by id. A finished ticket is consumed: its entry (and
    /// admission slot) is released and a second poll returns `NotFound`.
    pub fn poll(&self, id: u64) -> PollOutcome {
        let mut entries = self.entries.lock().unwrap();
        let now = Instant::now();
        entries.retain(|_, s| now.duration_since(s.created) < self.ttl);
        let Some(stored) = entries.get(&id) else {
            return PollOutcome::NotFound;
        };
        match stored.ticket.poll() {
            None => PollOutcome::Pending,
            Some(result) => {
                entries.remove(&id);
                PollOutcome::Done(result)
            }
        }
    }

    /// Live (unconsumed, unexpired) entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Whether the store holds no live entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::builder::EngineBuilder;
    use crate::fixed::{AccuracyClass, Precision};

    fn tiny_server() -> Server {
        let g = crate::graph::generators::watts_strogatz(64, 4, 0.2, 11);
        let cfg = RunConfig {
            precision: Precision::Fixed(26),
            kappa: 2,
            iterations: 3,
            batch_timeout_ms: 1,
            num_shards: 1,
            ..Default::default()
        };
        EngineBuilder::native().config(cfg).serve(&g, 1).expect("server starts")
    }

    fn serve_cfg() -> ServeConfig {
        ServeConfig { queue_cap: 4, ..Default::default() }
    }

    #[test]
    fn ticket_store_poll_consumes_once() {
        let server = tiny_server();
        let adm = Admission::new(&serve_cfg());
        let store = TicketStore::new(Duration::from_secs(60));

        let guard = adm.try_admit("default", AccuracyClass::Static).unwrap();
        let id = store.insert(server.submit(5, 3), guard);
        assert_eq!(store.len(), 1);
        assert_eq!(adm.depth("default", AccuracyClass::Static), 1);

        let deadline = Instant::now() + Duration::from_secs(10);
        let resp = loop {
            match store.poll(id) {
                PollOutcome::Pending => {
                    assert!(Instant::now() < deadline, "never resolved");
                    std::thread::sleep(Duration::from_millis(2));
                }
                PollOutcome::Done(result) => break result.expect("query succeeds"),
                PollOutcome::NotFound => panic!("ticket vanished while pending"),
            }
        };
        assert_eq!(resp.vertex, 5);
        assert_eq!(resp.ranking.len(), 3);
        // consumed: the entry and its admission slot are gone
        assert!(matches!(store.poll(id), PollOutcome::NotFound));
        assert!(store.is_empty());
        assert_eq!(adm.depth("default", AccuracyClass::Static), 0);
        server.shutdown();
    }

    #[test]
    fn ticket_store_expires_stale_entries() {
        let server = tiny_server();
        let adm = Admission::new(&serve_cfg());
        let store = TicketStore::new(Duration::from_millis(30));
        let guard = adm.try_admit("default", AccuracyClass::Static).unwrap();
        let id = store.insert(server.submit(1, 2), guard);
        std::thread::sleep(Duration::from_millis(50));
        // the TTL purge runs on poll: the entry is gone and its slot free
        assert!(matches!(store.poll(id), PollOutcome::NotFound));
        assert_eq!(adm.depth("default", AccuracyClass::Static), 0);
        server.shutdown();
    }

    #[test]
    fn unknown_ticket_is_not_found() {
        let store = TicketStore::new(Duration::from_secs(1));
        assert!(matches!(store.poll(424242), PollOutcome::NotFound));
    }
}
