//! Request routing and endpoint handlers of the HTTP front door.
//!
//! Endpoints (DESIGN.md §8; `curl` quickstart in the repo README):
//!
//! - `GET  /healthz` — liveness.
//! - `GET  /metrics` — Prometheus text exposition.
//! - `GET  /v1/graphs` — registry listing.
//! - `POST /v1/graphs/{name}/query` — synchronous PPR query.
//! - `POST /v1/graphs/{name}/submit` — asynchronous submission (202 +
//!   ticket id).
//! - `GET  /v1/tickets/{id}` — poll an async submission.
//!
//! Status mapping: malformed bodies and invalid query parameters → 400;
//! unknown graphs/tickets → 404; admission shed → 429 with `Retry-After`;
//! open circuit breaker → 503 with `Retry-After`; deadline misses → 504;
//! engine/transport faults → 500. Serving-core failures arrive as the
//! typed [`ServeError`] and map through [`ServeError::status`] — no
//! string matching — so the HTTP layer and the in-process API agree on
//! every rejection.

use super::http::{Request, Response};
use super::prom::CoreHealth;
use super::state::{PollOutcome, ServeState};
use crate::coordinator::request::{validate_query, PprResponse, ServeError};
use crate::coordinator::server::Ticket;
use crate::coordinator::EngineKind;
use crate::graph::VertexId;
use crate::util::json::{self, Json};
use crate::util::Stopwatch;
use std::time::Duration;

/// Default top-N when the request body omits `top_n` (an explicit 0 is a
/// 400 — see `QueryError::ZeroTopN`).
pub const DEFAULT_TOP_N: usize = 10;

/// Dispatch one request to its handler.
pub fn handle(state: &ServeState, req: &Request) -> Response {
    let segments = req.segments();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics(state),
        ("GET", ["v1", "graphs"]) => list_graphs(state),
        ("POST", ["v1", "graphs", name, "query"]) => query(state, *name, req, false),
        ("POST", ["v1", "graphs", name, "submit"]) => query(state, *name, req, true),
        ("GET", ["v1", "tickets", id]) => poll_ticket(state, *id),
        // known paths with the wrong verb get a 405, the rest 404
        (_, ["healthz" | "metrics"] | ["v1", "graphs", ..] | ["v1", "tickets", _]) => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, &format!("no route for {}", req.path)),
    }
}

fn healthz(state: &ServeState) -> Response {
    Response::json(
        200,
        &json::obj(vec![
            ("status", json::str("ok")),
            ("graphs", json::num(state.registry.len() as f64)),
        ]),
    )
}

fn metrics(state: &ServeState) -> Response {
    let depths = state.admission.snapshot();
    let snap = state.server.stats().snapshot();
    let health = state.server.worker_health();
    let core = CoreHealth {
        workers_live: health.live as u64,
        workers_total: health.total as u64,
        worker_respawns: health.respawns,
        stuck_batch_age_seconds: health.oldest_batch_age.as_secs_f64(),
        engine_panics: snap.panics,
        degraded_responses: snap.degraded,
        pool_caught_panics: crate::runtime::pool::global().caught_panics() as u64,
        breaker_states: state.breaker.states(),
        breaker_opens: state.breaker.opens(),
        breaker_cycles: state.breaker.cycles(),
        registry_resident_ram: state.registry.resident() as u64,
        registry_resident_disk: state.registry.resident_disk() as u64,
        registry_capacity: state.registry.capacity() as u64,
        artifact_hits: state.registry.artifact_hits(),
        backends: state.server.backends().to_vec(),
        dispatch: state.server.dispatch_stats(),
    };
    let text = state.metrics.render_with(&depths, &core);
    Response::text(200, "text/plain; version=0.0.4", text)
}

fn list_graphs(state: &ServeState) -> Response {
    let mut graphs = Vec::new();
    for name in state.registry.names() {
        graphs.push(json::obj(vec![
            ("name", json::str(name.as_ref())),
            (
                "num_vertices",
                json::num(state.registry.num_vertices(&name).unwrap_or(0) as f64),
            ),
            ("epoch", json::num(state.registry.epoch(&name).unwrap_or(0) as f64)),
            ("reloads", json::num(state.registry.reloads(&name).unwrap_or(0) as f64)),
        ]));
    }
    let default = match state.registry.default_graph() {
        Some(name) => json::str(name.as_ref()),
        None => Json::Null,
    };
    // dispatch surface: the routing policy plus which backends this server
    // actually stood up (a lane that failed its probe build is reported
    // unavailable, not omitted — clients can tell "off" from "broken")
    let available = state.server.backends();
    let backends: Vec<Json> = EngineKind::all()
        .iter()
        .map(|k| {
            json::obj(vec![
                ("backend", json::str(k.label())),
                ("available", Json::Bool(available.contains(k))),
            ])
        })
        .collect();
    let dispatch = json::obj(vec![
        ("policy", json::str(state.server.dispatch_policy().label())),
        ("backends", Json::Arr(backends)),
    ]);
    Response::json(
        200,
        &json::obj(vec![
            ("graphs", Json::Arr(graphs)),
            ("default", default),
            ("dispatch", dispatch),
        ]),
    )
}

/// Parsed body of a query/submit request.
struct QueryBody {
    vertices: Vec<u64>,
    top_n: usize,
    class: Option<String>,
    deadline_ms: Option<u64>,
}

fn parse_body(body: &[u8]) -> Result<QueryBody, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    if text.trim().is_empty() {
        return Err("missing JSON body".to_string());
    }
    let doc = Json::parse(text).map_err(|e| format!("malformed JSON body: {e:#}"))?;
    if !matches!(doc, Json::Obj(_)) {
        return Err("body must be a JSON object".to_string());
    }

    let vertices: Vec<u64> = match (doc.get("vertices"), doc.get("vertex")) {
        (Some(arr), _) => {
            let items = arr
                .as_array()
                .ok_or_else(|| "\"vertices\" must be an array".to_string())?;
            items
                .iter()
                .map(|v| {
                    v.as_u64()
                        .ok_or_else(|| "vertex ids must be non-negative integers".to_string())
                })
                .collect::<Result<_, _>>()?
        }
        (None, Some(v)) => {
            vec![v
                .as_u64()
                .ok_or_else(|| "\"vertex\" must be a non-negative integer".to_string())?]
        }
        (None, None) => return Err("missing \"vertices\" (or \"vertex\")".to_string()),
    };

    let top_n = match doc.get("top_n") {
        None => DEFAULT_TOP_N,
        Some(v) => v
            .as_u64()
            .ok_or_else(|| "\"top_n\" must be a non-negative integer".to_string())?
            as usize,
    };
    let class = match doc.get("class") {
        None => None,
        Some(v) => {
            Some(v.as_str().ok_or_else(|| "\"class\" must be a string".to_string())?.to_string())
        }
    };
    let deadline_ms = match doc.get("deadline_ms") {
        None => None,
        Some(v) => Some(
            v.as_u64()
                .ok_or_else(|| "\"deadline_ms\" must be a non-negative integer".to_string())?,
        ),
    };
    Ok(QueryBody { vertices, top_n, class, deadline_ms })
}

fn render_result(resp: &PprResponse) -> Json {
    let ranking: Vec<Json> = resp
        .ranking
        .iter()
        .map(|r| {
            json::obj(vec![
                ("vertex", json::num(f64::from(r.vertex))),
                ("score", json::num(r.score)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("vertex", json::num(f64::from(resp.vertex))),
        ("ranking", Json::Arr(ranking)),
        ("iterations", json::num(resp.iterations as f64)),
        ("escalations", json::num(resp.escalations as f64)),
        ("queue_ms", json::num(resp.queue_time.as_secs_f64() * 1e3)),
        ("total_ms", json::num(resp.total_time.as_secs_f64() * 1e3)),
    ];
    // only serialized when set, so fault-free responses stay byte-identical
    // to servers without the degradation policy
    if resp.degraded {
        fields.push(("degraded", Json::Bool(true)));
    }
    json::obj(fields)
}

/// Shared implementation of `query` (sync, waits) and `submit` (async,
/// returns a ticket). Every exit path records metrics under the graph's
/// client-facing name.
fn query(state: &ServeState, graph: &str, req: &Request, is_submit: bool) -> Response {
    let sw = Stopwatch::start();
    let finish = |label: &'static str, escalations: u64, resp: Response| -> Response {
        state.metrics.record(graph, label, resp.status, sw.seconds(), escalations);
        resp
    };

    let body = match parse_body(&req.body) {
        Ok(b) => b,
        Err(msg) => return finish("unknown", 0, Response::error(400, &msg)),
    };

    // route before validating vertex ranges (the range check needs |V|)
    let Some((key, num_vertices)) = state.registry.route(graph) else {
        return finish("unknown", 0, Response::error(404, &format!("unknown graph {graph}")));
    };

    // validate_query also clamps top_n to |V|; the clamped value is what
    // the engine actually serves, so the response length is honest
    let (parsed_class, top_n) =
        match validate_query(&body.vertices, body.top_n, body.class.as_deref(), num_vertices) {
            Ok(v) => v,
            Err(e) => return finish("unknown", 0, Response::error(400, &e.to_string())),
        };
    let class = parsed_class.unwrap_or_else(|| state.server.default_class());
    let label = class.label();

    if is_submit && body.vertices.len() != 1 {
        let msg = "submit accepts exactly one personalization vertex";
        return finish(label, 0, Response::error(400, msg));
    }

    // circuit breaker: fast-fail only when every backend that could serve
    // this class is held back — a breaker opened by CPU-baseline failures
    // never blocks traffic the dispatcher routes to healthy native lanes
    let candidates = state.server.candidate_backends(class);
    let admission = match state.breaker.check(&key, class, &candidates) {
        Ok(a) => a,
        Err(retry) => {
            let retry_ms = retry.as_millis() as u64;
            let err = ServeError::BreakerOpen { retry_after_ms: retry_ms };
            let resp = Response::error(err.status(), &err.to_string())
                .with_header("retry-after", format_retry_after(retry_ms));
            return finish(label, 0, resp);
        }
    };

    // admission: one slot per HTTP request, released when the guard drops
    let guard = match state.admission.try_admit(graph, class) {
        Ok(g) => g,
        Err(shed) => {
            // the breaker admitted this request (possibly reserving a
            // half-open probe slot) but no solve will run: return the
            // admission so the probe budget is never leaked
            state.breaker.release(&key, class, admission);
            let resp = Response::error(429, "overloaded, request shed")
                .with_header("retry-after", format_retry_after(shed.retry_after_ms));
            return finish(label, 0, resp);
        }
    };

    let deadline = body.deadline_ms.map(Duration::from_millis);
    let submit_one = |v: u64| -> Ticket {
        state.server.submit_to_class(key.as_ref(), v as VertexId, top_n, deadline, class)
    };

    if is_submit {
        let ticket = submit_one(body.vertices[0]);
        let id = state.tickets.insert(ticket, guard, admission);
        let body = json::obj(vec![
            ("ticket", json::num(id as f64)),
            ("graph", json::str(graph)),
            ("class", json::str(label)),
        ]);
        return finish(label, 0, Response::json(202, &body));
    }

    // sync: submit every vertex first (they batch together), then wait.
    // The breaker saw one check() for this HTTP request, so it gets
    // exactly one aggregate record back — per-ticket recording would let
    // a single admitted half-open probe close the breaker on its own
    // (N ticket successes >= half_open_probes after one request)
    let tickets: Vec<Ticket> = body.vertices.iter().map(|&v| submit_one(v)).collect();
    let mut results = Vec::with_capacity(tickets.len());
    let mut escalations = 0u64;
    // the stamp cell outlives wait(): the outcome is recorded against the
    // backend that actually served, not the one the breaker probed
    let mut served: Option<EngineKind> = None;
    for ticket in tickets {
        let stamp = ticket.served_by_cell();
        match ticket.wait() {
            Ok(resp) => {
                served = stamp.get().or(served);
                escalations += resp.escalations as u64;
                results.push(render_result(&resp));
            }
            Err(err) => {
                // only backend faults feed the breaker; deadline misses
                // and validation rejections are the client's problem
                let backend = stamp.get().or(served);
                state.breaker.record(&key, class, backend, admission, err.is_fault());
                drop(guard);
                return finish(label, escalations, Response::error(err.status(), &err.to_string()));
            }
        }
    }
    state.breaker.record(&key, class, served, admission, false);
    drop(guard);
    let body = json::obj(vec![
        ("graph", json::str(graph)),
        ("class", json::str(label)),
        ("results", Json::Arr(results)),
    ]);
    finish(label, escalations, Response::json(200, &body))
}

/// `Retry-After` is specified in whole seconds; round sub-second hints up
/// so clients never retry earlier than asked.
fn format_retry_after(ms: u64) -> String {
    ms.div_ceil(1000).max(1).to_string()
}

fn poll_ticket(state: &ServeState, id: &str) -> Response {
    let Ok(id) = id.parse::<u64>() else {
        return Response::error(400, "ticket id must be an integer");
    };
    match state.tickets.poll(id) {
        PollOutcome::NotFound => Response::error(404, "no such ticket"),
        PollOutcome::Pending => Response::json(
            200,
            &json::obj(vec![
                ("status", json::str("pending")),
                ("ticket", json::num(id as f64)),
            ]),
        ),
        PollOutcome::Done { graph, class, backend, admission, result: Ok(resp) } => {
            state.breaker.record(&graph, class, backend, admission, false);
            state.metrics.record(
                graph.as_ref(),
                class.label(),
                200,
                resp.total_time.as_secs_f64(),
                resp.escalations as u64,
            );
            Response::json(
                200,
                &json::obj(vec![
                    ("status", json::str("done")),
                    ("result", render_result(&resp)),
                ]),
            )
        }
        PollOutcome::Done { graph, class, backend, admission, result: Err(err) } => {
            let status = err.status();
            // the consumed entry carries its breaker key, so async-only
            // traffic feeds the breaker on failure exactly like sync
            // traffic does (a faulting probe must re-open, not leak)
            state.breaker.record(&graph, class, backend, admission, err.is_fault());
            state.metrics.record(graph.as_ref(), class.label(), status, 0.0, 0);
            Response::error(status, &err.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_after_rounds_up_to_whole_seconds() {
        assert_eq!(format_retry_after(1), "1");
        assert_eq!(format_retry_after(999), "1");
        assert_eq!(format_retry_after(1000), "1");
        assert_eq!(format_retry_after(1001), "2");
        assert_eq!(format_retry_after(0), "1", "zero hint still asks for a pause");
    }

    #[test]
    fn serve_errors_map_to_honest_statuses() {
        // the enum carries its own status — no string matching anywhere
        assert_eq!(ServeError::DeadlineQueue.status(), 504);
        assert_eq!(ServeError::DeadlineWait.status(), 504);
        assert_eq!(ServeError::GraphUnknown { name: "zz".into(), single: false }.status(), 404);
        assert_eq!(
            ServeError::VertexOutOfRange { vertex: 9, num_vertices: 5, after_reload: false }
                .status(),
            400
        );
        assert_eq!(ServeError::EngineFailed("shard fault".into()).status(), 500);
        assert_eq!(ServeError::BreakerOpen { retry_after_ms: 120 }.status(), 503);
        assert_eq!(ServeError::ChannelClosed.status(), 500);
    }

    #[test]
    fn degraded_flag_serializes_only_when_set() {
        use crate::fixed::AccuracyClass;
        use std::sync::Arc;
        use std::time::Duration;
        let mut resp = PprResponse {
            id: 1,
            graph: Arc::from("g"),
            class: AccuracyClass::Exact,
            vertex: 3,
            ranking: Vec::new(),
            iterations: 2,
            escalations: 0,
            queue_time: Duration::ZERO,
            total_time: Duration::ZERO,
            degraded: false,
        };
        let clean = render_result(&resp).render();
        assert!(!clean.contains("degraded"), "{clean}");
        resp.degraded = true;
        let flagged = render_result(&resp).render();
        assert!(flagged.contains("\"degraded\":true"), "{flagged}");
    }

    #[test]
    fn body_parser_accepts_both_vertex_forms() {
        let b = parse_body(br#"{"vertices":[1,2,3],"top_n":5}"#).unwrap();
        assert_eq!(b.vertices, vec![1, 2, 3]);
        assert_eq!(b.top_n, 5);
        assert!(b.class.is_none() && b.deadline_ms.is_none());

        let b = parse_body(br#"{"vertex":7,"class":"fast","deadline_ms":250}"#).unwrap();
        assert_eq!(b.vertices, vec![7]);
        assert_eq!(b.top_n, DEFAULT_TOP_N, "absent top_n takes the documented default");
        assert_eq!(b.class.as_deref(), Some("fast"));
        assert_eq!(b.deadline_ms, Some(250));
    }

    #[test]
    fn body_parser_rejects_malformed_input() {
        for bad in [
            &b""[..],
            br#"[1,2]"#,
            br#"{"top_n":3}"#,
            br#"{"vertices":"one"}"#,
            br#"{"vertices":[1.5]}"#,
            br#"{"vertices":[-1]}"#,
            br#"{"vertex":7,"top_n":"many"}"#,
            br#"{"vertex":7,"class":3}"#,
            br#"{"vertex":7,"deadline_ms":-5}"#,
            br#"{"vertex":7"#,
        ] {
            assert!(parse_body(bad).is_err(), "{:?} should fail", String::from_utf8_lossy(bad));
        }
    }
}
