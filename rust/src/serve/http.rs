//! Minimal HTTP/1.1 message framing over `std::net::TcpStream` (the
//! vendored crate set has no HTTP stack; see DESIGN.md §1). Exactly the
//! subset the front door needs: request-line + headers + `Content-Length`
//! bodies, keep-alive, and response serialization. No chunked encoding,
//! no TLS, no HTTP/2 — clients that need those sit behind a real proxy.
//!
//! Input bounds (hostile-client hardening): the head (request line +
//! headers) is capped at [`MAX_HEAD_BYTES`] and bodies at
//! [`MAX_BODY_BYTES`]; oversized input fails the parse instead of growing
//! buffers without bound.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::TcpStream;

/// Upper bound on request line + headers.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Upper bound on request bodies.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Method verb, uppercased by the client (`GET`, `POST`, ...).
    pub method: String,
    /// Path with query string stripped (`/v1/graphs/ws/query`).
    pub path: String,
    /// Header `(name, value)` pairs; names lowercased.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty without `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// First value of a header (name matched case-insensitively).
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == lower).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to close the connection after this
    /// exchange (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }

    /// The path split into non-empty `/`-separated segments.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// One HTTP response ready to serialize.
#[derive(Debug, Clone)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Extra headers beyond `Content-Type`/`Content-Length`/`Connection`.
    pub headers: Vec<(String, String)>,
    /// Content type sent with the body.
    pub content_type: &'static str,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, body: &crate::util::Json) -> Response {
        Response {
            status,
            headers: Vec::new(),
            content_type: "application/json",
            body: body.render().into_bytes(),
        }
    }

    /// A JSON error envelope: `{"error": "..."}`.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(
            status,
            &crate::util::json::obj(vec![("error", crate::util::json::str(message))]),
        )
    }

    /// A plain-text response (used by `/metrics`).
    pub fn text(status: u16, content_type: &'static str, body: String) -> Response {
        Response { status, headers: Vec::new(), content_type, body: body.into_bytes() }
    }

    /// Attach an extra header.
    pub fn with_header(mut self, name: &str, value: String) -> Response {
        self.headers.push((name.to_string(), value));
        self
    }

    /// Serialize onto the stream. `close` controls the `Connection`
    /// header (and must match whether the caller drops the stream).
    pub fn write_to(&self, stream: &mut TcpStream, close: bool) -> Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
            self.status,
            status_reason(self.status),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes()).context("write response head")?;
        stream.write_all(&self.body).context("write response body")?;
        stream.flush().context("flush response")?;
        Ok(())
    }
}

/// Canonical reason phrase for the status codes the front door emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Read one request off the stream. Returns `Ok(None)` on clean EOF
/// before any bytes (the peer closed an idle keep-alive connection);
/// malformed or oversized input is an error.
pub fn read_request(stream: &mut TcpStream) -> Result<Option<Request>> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];

    // read until the blank line ending the head
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("read request head")?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end]).context("non-utf8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().context("missing request line")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing request target")?;
    let path = target.split('?').next().unwrap_or(target).to_string();

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) =
            line.split_once(':').with_context(|| format!("malformed header {line:?}"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let content_length: usize = headers
        .iter()
        .find(|(n, _)| n == "content-length")
        .map(|(_, v)| v.parse().context("bad content-length"))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        bail!("request body exceeds {MAX_BODY_BYTES} bytes");
    }

    // body: whatever arrived after the head plus the remainder
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("read request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);

    Ok(Some(Request { method, path, headers, body }))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Client side: send `request_bytes` and read one full response. Returns
/// `(status, body)`. Shared by the load generator and the tests; assumes
/// the server frames responses with `Content-Length` (ours does).
pub fn roundtrip(stream: &mut TcpStream, request_bytes: &[u8]) -> Result<(u16, Vec<u8>)> {
    stream.write_all(request_bytes).context("write request")?;
    stream.flush().context("flush request")?;

    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            bail!("response head exceeds {MAX_HEAD_BYTES} bytes");
        }
        let n = stream.read(&mut chunk).context("read response head")?;
        if n == 0 {
            bail!("connection closed before response head");
        }
        buf.extend_from_slice(&chunk[..n]);
    };

    let head = std::str::from_utf8(&buf[..head_end]).context("non-utf8 response head")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().context("missing status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("missing status code")?
        .parse()
        .context("bad status code")?;
    let content_length: usize = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(n, _)| n.trim().eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.trim().parse().context("bad content-length"))
        .transpose()?
        .unwrap_or(0);

    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).context("read response body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok((status, body))
}

/// Build the bytes of a request (client side).
pub fn format_request(method: &str, path: &str, host: &str, body: Option<&str>) -> Vec<u8> {
    let body = body.unwrap_or("");
    format!(
        "{method} {path} HTTP/1.1\r\nhost: {host}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Run `server` on an accepted connection while the client closure
    /// drives the other end.
    fn with_pair<S, C, R>(server: S, client: C) -> R
    where
        S: FnOnce(TcpStream) + Send + 'static,
        C: FnOnce(TcpStream) -> R,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            server(stream);
        });
        let out = client(TcpStream::connect(addr).unwrap());
        handle.join().unwrap();
        out
    }

    #[test]
    fn parses_request_with_body_and_answers() {
        let (status, body) = with_pair(
            |mut stream| {
                let req = read_request(&mut stream).unwrap().unwrap();
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/graphs/ws/query");
                assert_eq!(req.segments(), vec!["v1", "graphs", "ws", "query"]);
                assert_eq!(req.header("Content-Type"), Some("application/json"));
                assert_eq!(req.body, b"{\"vertices\":[1]}");
                let ok = crate::util::json::obj(vec![("ok", crate::util::Json::Bool(true))]);
                Response::json(200, &ok).write_to(&mut stream, true).unwrap();
            },
            |mut stream| {
                let req = format_request(
                    "POST",
                    "/v1/graphs/ws/query?verbose=1",
                    "test",
                    Some("{\"vertices\":[1]}"),
                );
                roundtrip(&mut stream, &req).unwrap()
            },
        );
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
    }

    #[test]
    fn keep_alive_serves_sequential_requests() {
        let (a, b) = with_pair(
            |mut stream| {
                for _ in 0..2 {
                    let req = read_request(&mut stream).unwrap().unwrap();
                    assert!(!req.wants_close());
                    Response::text(200, "text/plain", format!("echo {}", req.path))
                        .write_to(&mut stream, false)
                        .unwrap();
                }
                let eof = read_request(&mut stream).unwrap();
                assert!(eof.is_none(), "clean EOF after client drop");
            },
            |mut stream| {
                let r1 = roundtrip(&mut stream, &format_request("GET", "/a", "t", None)).unwrap();
                let r2 = roundtrip(&mut stream, &format_request("GET", "/b", "t", None)).unwrap();
                (r1, r2)
            },
        );
        assert_eq!(a.1, b"echo /a");
        assert_eq!(b.1, b"echo /b");
    }

    #[test]
    fn rejects_oversized_body_declarations() {
        with_pair(
            |mut stream| {
                let err = read_request(&mut stream).unwrap_err();
                assert!(err.to_string().contains("body exceeds"), "{err:#}");
            },
            |mut stream| {
                let declared = MAX_BODY_BYTES + 1;
                let head = format!("POST /x HTTP/1.1\r\ncontent-length: {declared}\r\n\r\n");
                stream.write_all(head.as_bytes()).unwrap();
                stream.flush().unwrap();
                // wait for the server side to finish parsing
                let mut sink = Vec::new();
                let _ = stream.read_to_end(&mut sink);
            },
        );
    }

    #[test]
    fn rejects_malformed_request_line() {
        with_pair(
            |mut stream| {
                assert!(read_request(&mut stream).is_err());
            },
            |mut stream| {
                stream.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
                stream.flush().unwrap();
                let mut sink = Vec::new();
                let _ = stream.read_to_end(&mut sink);
            },
        );
    }

    #[test]
    fn response_carries_extra_headers() {
        let (status, _) = with_pair(
            |mut stream| {
                let _ = read_request(&mut stream).unwrap().unwrap();
                Response::error(429, "shed")
                    .with_header("retry-after", "1".to_string())
                    .write_to(&mut stream, true)
                    .unwrap();
            },
            |mut stream| {
                // raw read to inspect headers
                stream.write_all(&format_request("GET", "/", "t", None)).unwrap();
                let mut text = String::new();
                stream.read_to_string(&mut text).unwrap();
                assert!(text.contains("retry-after: 1"), "{text}");
                assert!(text.contains("429 Too Many Requests"), "{text}");
                (429u16, text)
            },
        );
        assert_eq!(status, 429);
    }
}
