//! The **adaptive precision ladder** (DESIGN.md §7) — the paper's claim
//! that reduced precision gives "precise control over the accuracy of the
//! results" turned into a runtime mechanism.
//!
//! A laddered run starts on the narrowest rung (e.g. Q1.15) and watches
//! the per-iteration update norm. Healthy PPR decay contracts the norm by
//! ≈ α per iteration; a reduced-precision datapath eventually hits its
//! quantization floor, where the norm plateaus (or the iteration reaches
//! an exact fixed point of the truncating arithmetic, norm 0). Either
//! signal means the rung has given all the accuracy it has — the ladder
//! then **hot-switches**: the double-buffered score vectors are
//! re-quantized into the next rung's format (an exact left shift for
//! fixed→fixed widening, [`FixedFormat::requantize`]) and the run resumes
//! on that rung's quantized value streams, warm-starting the wider
//! datapath from everything the cheap iterations already computed. The
//! final rung runs without a stall trigger until the tolerance or the
//! iteration budget.
//!
//! Invariants:
//!
//! - **monotone escalation**: rungs are visited in spec order, narrowest
//!   to widest, never descending (enforced by [`LadderSpec::validate`]
//!   and the construction — there is no descend path);
//! - **single-rung transparency**: a one-rung ladder performs exactly the
//!   word-level op sequence of the static engine under the same solver
//!   configuration — scores and f64 norms are bit-identical (pinned for
//!   both datapaths and shard counts 1 and 4 by the tests below);
//! - **re-quantization exactness**: widening fixed→fixed carries every
//!   bit of the narrow scores (raw << Δfrac); fixed→float converts
//!   through the exact f64 image of each word.
//!
//! Value streams are per-rung, per-precision — the registry caches them
//! per graph ([`crate::coordinator::GraphEntry::values`]) so the packet
//! schedule is shared across rungs and only the quantized words are
//! duplicated (DESIGN.md §7 on the schedule/value-stream cache split).

use super::batched::{BatchedPpr, Executor, SegmentStop};
use super::{copy_lane, PprConfig, PreparedGraph};
use crate::fixed::{FixedFormat, LadderSpec, Precision};
use crate::graph::VertexId;
use crate::spmv::datapath::{FixedPath, FloatPath};
use crate::spmv::topk::RankedLanes;
use crate::util::mmap::PodVec;
use std::sync::Arc;

/// Per-shard value streams quantized for one precision — the unit of the
/// registry's per-precision cache. `Arc`-shared: every engine and every
/// ladder rung bound to the same `(graph, precision)` reads one copy.
/// Each shard's stream is a [`PodVec`]: owned when quantized in RAM,
/// zero-copy when served out of a mapped schedule artifact.
#[derive(Debug, Clone)]
pub enum ValueStreams {
    /// Raw fixed-point words (any Q1.n rung).
    Fixed(Arc<Vec<PodVec<u64>>>),
    /// IEEE f32 words (the float rung / engine).
    Float(Arc<Vec<PodVec<f32>>>),
}

impl ValueStreams {
    /// Quantize a prepared graph's shard streams for `precision`.
    pub fn quantize(prepared: &PreparedGraph, precision: Precision) -> ValueStreams {
        match precision {
            Precision::Fixed(w) => ValueStreams::Fixed(Arc::new(
                prepared.sharded.quantize_values_for(&FixedPath::paper(w)),
            )),
            Precision::Float32 => {
                ValueStreams::Float(Arc::new(prepared.sharded.quantize_values_for(&FloatPath)))
            }
        }
    }

    /// Total resident words across shards (cache accounting).
    pub fn num_words(&self) -> usize {
        match self {
            ValueStreams::Fixed(v) => v.iter().map(|s| s.len()).sum(),
            ValueStreams::Float(v) => v.iter().map(|s| s.len()).sum(),
        }
    }
}

/// One rung's share of a ladder run (the escalation trace).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RungSegment {
    /// The rung's precision.
    pub precision: Precision,
    /// Iterations executed on this rung.
    pub iterations: usize,
    /// Why the segment ended (non-terminal segments always
    /// [`SegmentStop::Stalled`]).
    pub stop: SegmentStop,
}

/// Final scores of a ladder run, in the terminal rung's representation.
#[derive(Debug, Clone)]
pub enum LadderScores {
    /// Vertex-major raw words plus their format.
    Fixed(Vec<u64>, FixedFormat),
    /// Vertex-major f32 scores.
    Float(Vec<f32>),
}

impl LadderScores {
    /// The precision the scores are expressed in.
    pub fn precision(&self) -> Precision {
        match self {
            LadderScores::Fixed(_, fmt) => Precision::Fixed(fmt.total_bits()),
            LadderScores::Float(_) => Precision::Float32,
        }
    }

    /// Dequantized lane `k` of a `lanes`-wide vertex-major block.
    pub fn lane_f64(&self, lanes: usize, k: usize) -> Vec<f64> {
        match self {
            LadderScores::Fixed(words, fmt) => {
                copy_lane(words, lanes, k).into_iter().map(|w| fmt.to_f64(w)).collect()
            }
            LadderScores::Float(words) => {
                copy_lane(words, lanes, k).into_iter().map(|w| w as f64).collect()
            }
        }
    }
}

/// The outcome of one laddered PPR run.
#[derive(Debug, Clone)]
pub struct LadderOutput {
    /// Final scores (terminal rung's representation), `num_vertices ×
    /// lanes`, vertex-major.
    pub scores: LadderScores,
    /// Lanes the run carried.
    pub lanes: usize,
    /// Total iterations across all rungs.
    pub iterations: usize,
    /// Per-iteration update norms, concatenated across rungs.
    pub update_norms: Vec<f64>,
    /// The escalation trace, in rung order.
    pub segments: Vec<RungSegment>,
    /// Top-K-native result (`Some` iff `cfg.top_k` was set): the terminal
    /// rung's ranking — each rung switch fully re-seeds the candidate
    /// heaps (narrow and wide words are incomparable), and heaps rebuild
    /// every iteration, so no candidate can be lost across an escalation.
    /// The write-back pruning ledger accumulates across all segments.
    pub topk: Option<RankedLanes>,
}

impl LadderOutput {
    /// Precision of the rung that produced the final scores.
    pub fn final_precision(&self) -> Precision {
        self.scores.precision()
    }
}

/// One per-rung engine (each holds its own quantized value streams; all
/// share the one packet schedule through the `Arc<PreparedGraph>`).
enum Rung {
    Fixed(BatchedPpr<FixedPath>),
    Float(BatchedPpr<FloatPath>),
}

/// The laddered PPR engine: a stack of [`BatchedPpr`] rungs over one
/// prepared graph, driven segment by segment. See the module docs.
pub struct LadderPpr {
    spec: LadderSpec,
    kappa: usize,
    graph: Arc<PreparedGraph>,
    rungs: Vec<Rung>,
}

impl LadderPpr {
    /// Build a ladder over a prepared graph, quantizing each rung's value
    /// streams here (like loading every precision's partitions onto their
    /// channels once). Panics on an invalid [`LadderSpec`].
    pub fn new(graph: Arc<PreparedGraph>, spec: LadderSpec, kappa: usize, alpha: f64) -> Self {
        let g = graph.clone();
        Self::with_streams(graph, spec, kappa, alpha, Executor::Fused, move |p| {
            ValueStreams::quantize(&g, p)
        })
    }

    /// Build a ladder over **already-quantized** per-rung value streams —
    /// the registry path, where streams are cached per `(graph,
    /// precision)` and shared across workers and rungs. Panics on an
    /// invalid spec or a stream whose word type mismatches its rung.
    pub fn with_streams(
        graph: Arc<PreparedGraph>,
        spec: LadderSpec,
        kappa: usize,
        alpha: f64,
        executor: Executor,
        mut streams: impl FnMut(Precision) -> ValueStreams,
    ) -> Self {
        if let Err(e) = spec.validate() {
            panic!("invalid ladder spec: {e}");
        }
        let rungs = spec
            .rungs
            .iter()
            .map(|&p| match (p, streams(p)) {
                (Precision::Fixed(w), ValueStreams::Fixed(vals)) => Rung::Fixed(
                    BatchedPpr::with_shared_values(
                        FixedPath::paper(w),
                        graph.clone(),
                        vals,
                        kappa,
                        alpha,
                    )
                    .with_executor(executor),
                ),
                (Precision::Float32, ValueStreams::Float(vals)) => Rung::Float(
                    BatchedPpr::with_shared_values(FloatPath, graph.clone(), vals, kappa, alpha)
                        .with_executor(executor),
                ),
                (p, _) => panic!("value streams for rung {p} carry the wrong word type"),
            })
            .collect();
        Self { spec, kappa, graph, rungs }
    }

    /// The ladder this engine climbs.
    pub fn spec(&self) -> &LadderSpec {
        &self.spec
    }

    /// Maximum lanes per run.
    pub fn kappa(&self) -> usize {
        self.kappa
    }

    /// |V| of the bound graph.
    pub fn num_vertices(&self) -> usize {
        self.graph.num_vertices
    }

    /// Shards (compute units) every rung sweeps.
    pub fn num_shards(&self) -> usize {
        self.graph.num_shards()
    }

    /// Run Alg. 1 up the ladder for 1..=κ personalization vertices.
    ///
    /// The effective tolerance is `cfg.convergence_threshold` when set,
    /// else the spec's; `cfg.max_iterations` is the total budget across
    /// rungs. Non-final rungs run with the spec's stall trigger and
    /// escalate on [`SegmentStop::Stalled`]; any other stop ends the run.
    ///
    /// The returned scores are an owned copy (one `n·κ` copy per run, on
    /// top of the inter-rung re-quantization copies): segments of
    /// different rungs live in different engines' scratch buffers, so a
    /// `PprRun`-style borrow of "whichever rung finished" is not
    /// expressible without boxing — the copy is ≪ 1% of a
    /// convergence-driven run's sweep work.
    pub fn run(&mut self, personalization: &[VertexId], cfg: &PprConfig) -> LadderOutput {
        let threshold = cfg.convergence_threshold.unwrap_or(self.spec.tolerance);
        let budget = cfg.max_iterations;
        let nrungs = self.rungs.len();
        let mut segments: Vec<RungSegment> = Vec::with_capacity(nrungs);
        let mut update_norms: Vec<f64> = Vec::new();
        let mut total = 0usize;
        // scores carried between rungs, in the previous rung's format
        let mut carried: Option<LadderScores> = None;
        // newest rung's ranking; the pruning ledger sums over segments
        let mut topk: Option<RankedLanes> = None;

        for i in 0..nrungs {
            let last = i + 1 == nrungs;
            let remaining = budget.saturating_sub(total);
            if remaining == 0 && i > 0 {
                break; // budget exhausted mid-ladder: last rung's result stands
            }
            let seg_cfg = PprConfig {
                alpha: cfg.alpha,
                max_iterations: remaining,
                convergence_threshold: Some(threshold),
                top_k: cfg.top_k,
            };
            let stall = if last { None } else { Some(self.spec.stall_ratio) };
            let (stop, iterations, scores, seg_topk) = match &mut self.rungs[i] {
                Rung::Fixed(engine) => {
                    let fmt = engine.datapath.fmt;
                    // re-quantize the carried scores into this rung's
                    // format (exact for the monotone widening the spec
                    // enforces)
                    let init: Option<Vec<u64>> = carried.take().map(|c| match c {
                        LadderScores::Fixed(words, from) => {
                            words.iter().map(|&w| from.requantize(&fmt, w)).collect()
                        }
                        LadderScores::Float(_) => {
                            unreachable!("Float32 only terminates a ladder")
                        }
                    });
                    let (stop, run) =
                        engine.run_segment(personalization, &seg_cfg, init.as_deref(), stall);
                    update_norms.extend_from_slice(&run.update_norms);
                    (
                        stop,
                        run.iterations,
                        LadderScores::Fixed(run.scores.to_vec(), fmt),
                        run.topk,
                    )
                }
                Rung::Float(engine) => {
                    let init: Option<Vec<f32>> = carried.take().map(|c| match c {
                        LadderScores::Fixed(words, from) => {
                            words.iter().map(|&w| from.to_f64(w) as f32).collect()
                        }
                        LadderScores::Float(words) => words,
                    });
                    let (stop, run) =
                        engine.run_segment(personalization, &seg_cfg, init.as_deref(), stall);
                    update_norms.extend_from_slice(&run.update_norms);
                    (stop, run.iterations, LadderScores::Float(run.scores.to_vec()), run.topk)
                }
            };
            total += iterations;
            segments.push(RungSegment { precision: self.spec.rungs[i], iterations, stop });
            carried = Some(scores);
            if let Some(mut r) = seg_topk {
                // the heaps were fully re-seeded for this rung (word
                // formats are incomparable across rungs), so this rung's
                // ranking replaces the previous one; the write-back ledger
                // keeps counting across the whole run
                if let Some(prev) = topk.take() {
                    r.writeback_words_saved += prev.writeback_words_saved;
                    for (a, b) in r.saved_per_shard.iter_mut().zip(&prev.saved_per_shard) {
                        *a += *b;
                    }
                }
                topk = Some(r);
            }
            if stop != SegmentStop::Stalled {
                break; // converged (or budget ran dry): the ladder is done
            }
        }

        LadderOutput {
            scores: carried.expect("the first rung always runs"),
            lanes: personalization.len(),
            iterations: total,
            update_norms,
            segments,
            topk,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::AccuracyClass;
    use crate::graph::CooMatrix;
    use crate::ppr::reference;

    fn coo() -> CooMatrix {
        CooMatrix::from_graph(&crate::graph::generators::holme_kim(260, 4, 0.25, 23))
    }

    #[test]
    fn single_rung_ladder_bit_identical_to_static_engine() {
        let coo = coo();
        let pers: Vec<VertexId> = vec![2, 7, 11];
        let cfg = PprConfig {
            max_iterations: 40,
            convergence_threshold: Some(1e-6),
            ..Default::default()
        };
        for shards in [1usize, 4] {
            let pg = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, shards));
            // fixed datapath
            let d = FixedPath::paper(24);
            let base = BatchedPpr::new(d, pg.clone(), 3, 0.85).run(&pers, &cfg);
            let spec = LadderSpec::single(Precision::Fixed(24), 1e-6, 40);
            let out = LadderPpr::new(pg.clone(), spec, 3, 0.85).run(&pers, &cfg);
            match &out.scores {
                LadderScores::Fixed(words, fmt) => {
                    assert_eq!(words, &base.scores, "shards={shards}: score words");
                    assert_eq!(fmt.total_bits(), 24);
                }
                other => panic!("expected fixed scores, got {other:?}"),
            }
            assert_eq!(out.update_norms, base.update_norms, "shards={shards}: f64 norms");
            assert_eq!(out.iterations, base.iterations);
            assert_eq!(out.segments.len(), 1);

            // float datapath
            let basef = BatchedPpr::new(FloatPath, pg.clone(), 3, 0.85).run(&pers, &cfg);
            let specf = LadderSpec::single(Precision::Float32, 1e-6, 40);
            let outf = LadderPpr::new(pg, specf, 3, 0.85).run(&pers, &cfg);
            match &outf.scores {
                LadderScores::Float(words) => assert_eq!(words, &basef.scores, "shards={shards}"),
                other => panic!("expected float scores, got {other:?}"),
            }
            assert_eq!(outf.update_norms, basef.update_norms);
        }
    }

    #[test]
    fn escalation_is_monotone_and_never_descends() {
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 2));
        let spec = AccuracyClass::Balanced.ladder().unwrap();
        let budget = spec.max_iterations;
        let mut ladder = LadderPpr::new(pg, spec, 2, 0.85);
        let cfg = PprConfig { max_iterations: budget, ..Default::default() };
        let out = ladder.run(&[3, 11], &cfg);
        assert!(
            out.segments.len() >= 2,
            "Q1.15 cannot reach 1e-6 (its smallest nonzero norm is ~2^-15), so the \
             ladder must escalate: {:?}",
            out.segments
        );
        for pair in out.segments.windows(2) {
            assert!(
                pair[1].precision.bits() > pair[0].precision.bits(),
                "escalation must widen monotonically: {:?}",
                out.segments
            );
        }
        for seg in &out.segments[..out.segments.len() - 1] {
            assert_eq!(seg.stop, SegmentStop::Stalled, "non-terminal segments escalate");
        }
        assert_eq!(
            out.segments.iter().map(|s| s.iterations).sum::<usize>(),
            out.iterations
        );
        assert_eq!(out.update_norms.len(), out.iterations);
        assert!(out.iterations <= budget, "ladder respects the total budget");
    }

    #[test]
    fn exact_class_matches_float_reference_within_paper_tolerance() {
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo(&coo, 8));
        let spec = AccuracyClass::Exact.ladder().unwrap();
        let budget = spec.max_iterations;
        let mut ladder = LadderPpr::new(pg, spec, 1, 0.85);
        let cfg = PprConfig { max_iterations: budget, ..Default::default() };
        let out = ladder.run(&[9], &cfg);
        assert_eq!(
            out.final_precision(),
            Precision::Float32,
            "exact terminates on the float rung: {:?}",
            out.segments
        );
        let truth = reference::ppr_f64(&coo, 9, 0.85, 150, Some(1e-12));
        let got = out.scores.lane_f64(1, 0);
        for v in 0..coo.num_vertices {
            assert!(
                (got[v] - truth.scores[v]).abs() < 1e-4,
                "vertex {v}: {} vs {}",
                got[v],
                truth.scores[v]
            );
        }
    }

    #[test]
    fn warm_start_beats_cold_start_on_the_final_rung() {
        // the ladder's point: the wide rung resumes from the narrow rungs'
        // work, so it needs strictly fewer wide iterations than a
        // cold-started wide engine run to the same tolerance
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo(&coo, 8));
        let tol = 1e-6;
        let cfg = PprConfig {
            max_iterations: 200,
            convergence_threshold: Some(tol),
            ..Default::default()
        };
        let cold = BatchedPpr::new(FixedPath::paper(26), pg.clone(), 1, 0.85).run(&[5], &cfg);
        let spec = AccuracyClass::Balanced.ladder().unwrap();
        let out = LadderPpr::new(pg, spec, 1, 0.85).run(&[5], &cfg);
        let wide_iters = out
            .segments
            .iter()
            .filter(|s| s.precision == Precision::Fixed(26))
            .map(|s| s.iterations)
            .sum::<usize>();
        assert!(
            wide_iters < cold.iterations,
            "warm-started Q1.25 segment ({wide_iters} iters) must undercut the \
             cold start ({} iters)",
            cold.iterations
        );
    }

    #[test]
    fn topk_survives_rung_escalation() {
        // the escalation path must re-seed the heaps per rung without
        // losing candidates: the final ranking has to equal a dense top-N
        // extraction of the ladder's own final scores, exactly
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 2));
        let spec = AccuracyClass::Balanced.ladder().unwrap();
        let budget = spec.max_iterations;
        let mut ladder = LadderPpr::new(pg, spec, 2, 0.85);
        let kk = 15usize;
        let cfg = PprConfig { max_iterations: budget, top_k: Some(kk), ..Default::default() };
        let out = ladder.run(&[3, 11], &cfg);
        assert!(out.segments.len() >= 2, "must escalate to exercise the re-seed");
        let ranked = out.topk.expect("top_k was set");
        assert_eq!(ranked.k, kk);
        for lane in 0..2 {
            let dense = out.scores.lane_f64(2, lane);
            let want = crate::metrics::top_n_indices_f64(&dense, kk);
            let got: Vec<usize> =
                ranked.lanes[lane].iter().map(|&(v, _)| v as usize).collect();
            assert_eq!(got, want, "lane {lane}: ranking lost candidates across rungs");
            for (i, &(_, s)) in ranked.lanes[lane].iter().enumerate() {
                assert_eq!(s, dense[want[i]], "lane {lane} rank {i}: score mismatch");
            }
        }
        // every segment ran with heaps engaged, so the ledger spans them
        assert!(ranked.writeback_words_saved > 0, "no pruning counted across the run");
    }

    #[test]
    fn topk_none_leaves_ladder_output_unranked() {
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo(&coo, 8));
        let spec = LadderSpec::single(Precision::Fixed(24), 1e-6, 20);
        let out = LadderPpr::new(pg, spec, 1, 0.85)
            .run(&[4], &PprConfig { max_iterations: 20, ..Default::default() });
        assert!(out.topk.is_none());
    }

    #[test]
    fn value_streams_quantize_per_precision() {
        let coo = coo();
        let pg = PreparedGraph::from_coo_sharded(&coo, 8, 3);
        let fixed = ValueStreams::quantize(&pg, Precision::Fixed(20));
        let float = ValueStreams::quantize(&pg, Precision::Float32);
        assert_eq!(fixed.num_words(), float.num_words(), "same slots, different words");
        match fixed {
            ValueStreams::Fixed(v) => assert_eq!(v.len(), 3, "one stream per shard"),
            _ => panic!("fixed precision yields fixed words"),
        }
        match float {
            ValueStreams::Float(v) => assert_eq!(v.len(), 3),
            _ => panic!("float precision yields float words"),
        }
    }

    #[test]
    #[should_panic(expected = "invalid ladder spec")]
    fn invalid_spec_rejected_at_construction() {
        let coo = coo();
        let pg = Arc::new(PreparedGraph::from_coo(&coo, 8));
        let spec = LadderSpec {
            rungs: vec![Precision::Fixed(26), Precision::Fixed(20)],
            tolerance: 1e-6,
            stall_ratio: 0.95,
            max_iterations: 10,
        };
        let _ = LadderPpr::new(pg, spec, 1, 0.85);
    }
}
