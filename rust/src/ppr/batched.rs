//! Alg. 1 — κ-batched Personalized PageRank on the streaming SpMV engine,
//! generic over the arithmetic datapath. This is the bit-accurate software
//! model of the FPGA computation: every multiply, add and quantization
//! happens exactly where the hardware datapath performs it.
//!
//! The engine is **sharded** (DESIGN.md §4) and, by default, **fused**
//! (DESIGN.md §5): instead of three passes over the `n·κ` score vectors
//! per iteration (dangling scan → sharded scatter → Eq. 1 update), the
//! fused executor makes **one** — the scatter's clamp epilogue applies
//! Eq. 1, accumulates the update norm, and computes the *next*
//! iteration's per-shard dangling partial in the same sweep
//! ([`crate::spmv::fast`]'s `scatter_fused`). `P₁`/`P₂` become a
//! double-buffered pair that swaps each iteration rather than two
//! separately-written vectors, and the scratch buffers persist across
//! `run` calls, so the steady-state request path allocates nothing big.
//! All fan-outs run on the persistent worker pool
//! ([`crate::runtime::pool`]) — zero thread spawns per iteration.
//!
//! Bit-identity: the fused sweep performs, per output word, exactly the
//! word-level op sequence of the unfused engine (clamp, ×α, +scaling,
//! +(1−α) at the personalization vertex; dangling partials folded per
//! shard in ascending-vertex order, shards folded in shard order), so
//! fused and unfused runs produce identical score words — and identical
//! f64 update norms — for **both** datapaths at any fixed shard count.
//! Across shard counts, the fixed-point datapath's score words are still
//! bit-identical every iteration (saturating adds of non-negative values
//! give `min(Σ, max)` under any grouping), while the float datapath may
//! differ in the last ulp of the dangling sum, exactly like a per-CU
//! hardware reduction tree would.
//!
//! One caveat (unchanged by fusion): the reported update norm is an f64
//! reduction whose grouping follows the shards (deterministic for a fixed
//! shard count, but not identical across shard counts — f64 addition is
//! not associative). A `convergence_threshold` that lands within an ulp
//! of the norm can therefore stop at a different iteration for different
//! shard counts; fixed-iteration runs (the paper's timed configuration)
//! are unaffected.

use super::{PprConfig, PreparedGraph};
use crate::graph::VertexId;
use crate::spmv::fast::{scatter_fused, FusedUpdate};
use crate::spmv::shard::{fan_out, fan_out_mode, PARALLEL_WORK_PER_SHARD};
use crate::spmv::topk::{merge_shard_heaps, LaneHeaps, MergedTopK, RankedLanes};
use crate::spmv::Datapath;
use crate::util::mmap::PodVec;
use std::sync::Arc;

/// How [`BatchedPpr`] executes one PPR iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// One fused sweep per iteration on the persistent worker pool —
    /// scatter, Eq. 1 update, norm and next-iteration dangling partial in
    /// a single pass (the default; config `engine.fused`, CLI
    /// `--no-fused` to opt out).
    Fused,
    /// The three-sweep engine (dangling scan, edge stream, Eq. 1 update),
    /// still on the persistent pool — the `--no-fused` escape hatch.
    Unfused,
    /// The three-sweep engine with scoped thread spawns per sweep: the
    /// pre-pool execution mode, kept only as the measured baseline of the
    /// `fusion_speedup` bench.
    UnfusedScoped,
}

impl Executor {
    /// Label for engine descriptions and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Executor::Fused => "fused",
            Executor::Unfused => "unfused",
            Executor::UnfusedScoped => "unfused-scoped",
        }
    }
}

/// Why a [`BatchedPpr::run_segment`] call stopped — the escalation signal
/// of the adaptive precision ladder (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentStop {
    /// The update norm fell below the convergence threshold.
    Converged,
    /// The norm stalled above the threshold (shrank by less than the
    /// stall ratio between consecutive iterations): the datapath has hit
    /// its quantization floor and a wider rung should take over.
    Stalled,
    /// The iteration budget ran out first.
    Budget,
}

/// Result of one batched PPR run (owned copy of the scores).
#[derive(Debug, Clone)]
pub struct PprOutput<W> {
    /// Final scores, `num_vertices × lanes`, vertex-major
    /// (`scores[v*lanes + k]`).
    pub scores: Vec<W>,
    /// Lanes this run carried (≤ the engine's κ for partial batches).
    pub lanes: usize,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Per-iteration Euclidean norm of the update, averaged over lanes
    /// (the convergence signal of Fig. 7).
    pub update_norms: Vec<f64>,
    /// Top-K-native result (`Some` iff `cfg.top_k` was set): per-lane
    /// ranked `(vertex, score)` lists plus the write-back pruning ledger.
    /// The dense `scores` stay valid alongside it.
    pub topk: Option<RankedLanes>,
}

impl<W: Copy> PprOutput<W> {
    /// Extract lane `k` as a dense vector. The stride is the run's actual
    /// lane count (partial batches carry fewer lanes than the engine's κ).
    pub fn lane(&self, k: usize) -> Vec<W> {
        assert!(k < self.lanes, "lane {k} out of range (run carried {})", self.lanes);
        copy_lane(&self.scores, self.lanes, k)
    }
}

/// Result of one run viewed in the engine's scratch buffer — the
/// zero-copy variant of [`PprOutput`] used by the serving path (the
/// engine's scratch persists across runs; copy what you need before the
/// next `run_scratch`).
#[derive(Debug)]
pub struct PprRun<'a, W> {
    /// Final scores, `num_vertices × lanes`, vertex-major, borrowed from
    /// the engine's reusable scratch.
    pub scores: &'a [W],
    /// Lanes this run carried.
    pub lanes: usize,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Per-iteration update norms.
    pub update_norms: Vec<f64>,
    /// Top-K-native result (`Some` iff `cfg.top_k` was set) — see
    /// [`PprOutput::topk`].
    pub topk: Option<RankedLanes>,
}

/// Extract lane `k` from a vertex-major block of `lanes`-word rows by
/// chunked slice iteration — measurably faster than the old
/// `skip(k).step_by(lanes)` iterator collect on large `n` (the optimizer
/// sees a strided copy instead of an opaque iterator chain).
pub fn copy_lane<W: Copy>(scores: &[W], lanes: usize, k: usize) -> Vec<W> {
    assert!(lanes >= 1 && k < lanes);
    if lanes == 1 {
        return scores.to_vec();
    }
    let mut out = Vec::with_capacity(scores.len() / lanes);
    for row in scores.chunks_exact(lanes) {
        out.push(row[k]);
    }
    out
}

/// Batched PPR engine bound to a prepared graph and a datapath.
pub struct BatchedPpr<D: Datapath> {
    /// Arithmetic datapath.
    pub datapath: D,
    /// Maximum lanes per pass (a run may carry fewer).
    pub kappa: usize,
    graph: Arc<PreparedGraph>,
    /// Per-shard quantized value streams (the per-CU channel contents).
    /// `Arc`-shared so every engine of one `(graph, precision)` pair —
    /// worker-pool replicas, ladder rungs — reads one resident copy.
    vals: Arc<Vec<PodVec<D::Word>>>,
    // quantized constants of Eq. 1
    alpha: D::Word,
    one_minus_alpha: D::Word,
    alpha_over_v: D::Word,
    executor: Executor,
    // scratch reused across `run` calls (previously 2·n·κ words were
    // allocated per request): the double-buffered score pair + the
    // per-lane scaling vector, sized lazily to the widest run seen
    cur: Vec<D::Word>,
    nxt: Vec<D::Word>,
    scaling: Vec<D::Word>,
    // top-K-native scratch: one streaming candidate-heap state per shard
    // plus the cross-shard merge buffer; empty until a run sets
    // `cfg.top_k`, fully re-seeded at every segment start (ladder rungs
    // change word formats, so nothing may carry across segments)
    heaps: Vec<LaneHeaps<D::Word>>,
    merged: MergedTopK<D::Word>,
}

impl<D: Datapath> BatchedPpr<D> {
    /// Bind an engine to a prepared graph. `alpha` is quantized once here,
    /// like the synthesized constants of the bitstream; each shard's value
    /// stream is quantized once, like loading the partitions onto their
    /// channels (§4.2). The executor defaults to [`Executor::Fused`].
    pub fn new(datapath: D, graph: Arc<PreparedGraph>, kappa: usize, alpha: f64) -> Self {
        let vals = Arc::new(graph.sharded.quantize_values_for(&datapath));
        Self::with_shared_values(datapath, graph, vals, kappa, alpha)
    }

    /// Bind an engine to a prepared graph over **already-quantized** value
    /// streams (one per shard, quantized via
    /// [`crate::spmv::ShardedSchedule::quantize_values_for`]) — the
    /// registry's per-precision value-stream cache hands every worker and
    /// every ladder rung the same `Arc` instead of re-quantizing per
    /// engine.
    pub fn with_shared_values(
        datapath: D,
        graph: Arc<PreparedGraph>,
        vals: Arc<Vec<PodVec<D::Word>>>,
        kappa: usize,
        alpha: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&alpha));
        assert_eq!(
            vals.len(),
            graph.sharded.num_shards(),
            "one value stream per shard"
        );
        for (v, s) in vals.iter().zip(&graph.sharded.shards) {
            assert_eq!(v.len(), s.num_slots(), "value stream length of a shard");
        }
        let alpha_w = datapath.quantize(alpha);
        let one_minus_alpha = datapath.quantize(1.0 - alpha);
        let alpha_over_v = datapath.quantize(alpha / graph.num_vertices as f64);
        Self {
            datapath,
            kappa,
            graph,
            vals,
            alpha: alpha_w,
            one_minus_alpha,
            alpha_over_v,
            executor: Executor::Fused,
            cur: Vec::new(),
            nxt: Vec::new(),
            scaling: Vec::new(),
            heaps: Vec::new(),
            merged: MergedTopK::new(),
        }
    }

    /// Select the iteration executor (builder-style).
    pub fn with_executor(mut self, executor: Executor) -> Self {
        self.executor = executor;
        self
    }

    /// The iteration executor this engine runs.
    pub fn executor(&self) -> Executor {
        self.executor
    }

    /// Number of shards (parallel compute units) the engine sweeps.
    pub fn num_shards(&self) -> usize {
        self.graph.sharded.num_shards()
    }

    /// Run Alg. 1 for a batch of 1..=κ personalization vertices,
    /// returning an owned copy of the scores. Partial batches are
    /// first-class: compute scales with the lanes actually carried, and
    /// each lane is bit-identical to the same lane of any other batch
    /// shape (lanes never interact).
    pub fn run(&mut self, personalization: &[VertexId], cfg: &PprConfig) -> PprOutput<D::Word> {
        let run = self.run_scratch(personalization, cfg);
        PprOutput {
            scores: run.scores.to_vec(),
            lanes: run.lanes,
            iterations: run.iterations,
            update_norms: run.update_norms,
            topk: run.topk,
        }
    }

    /// Run Alg. 1 leaving the final scores in the engine's reusable
    /// scratch buffer — the allocation-free serving path ([`PprRun`]
    /// borrows the scratch; the next `run_scratch` overwrites it).
    pub fn run_scratch(
        &mut self,
        personalization: &[VertexId],
        cfg: &PprConfig,
    ) -> PprRun<'_, D::Word> {
        self.run_segment(personalization, cfg, None, None).1
    }

    /// One **segment** of Alg. 1 — the unit the adaptive precision ladder
    /// drives (DESIGN.md §7). Identical to [`Self::run_scratch`] except:
    ///
    /// - `resume`: start from the given `n·κ` vertex-major scores (a
    ///   previous rung's result re-quantized into this datapath) instead
    ///   of the V̄ initialization;
    /// - `stall_ratio`: stop with [`SegmentStop::Stalled`] once the norm
    ///   fails to shrink below `ratio ×` the previous iteration's norm
    ///   while still above the convergence threshold.
    ///
    /// With `resume = None` and `stall_ratio = None` the word-level op
    /// sequence is exactly `run_scratch`'s, so a single-rung ladder is
    /// bit-identical to the static engine.
    pub fn run_segment(
        &mut self,
        personalization: &[VertexId],
        cfg: &PprConfig,
        resume: Option<&[D::Word]>,
        stall_ratio: Option<f64>,
    ) -> (SegmentStop, PprRun<'_, D::Word>) {
        let k = personalization.len();
        assert!(
            k >= 1 && k <= self.kappa,
            "batch of {k} lanes outside 1..=κ ({})",
            self.kappa
        );
        let d = self.datapath.clone();
        let n = self.graph.num_vertices;
        let z = d.zero();
        let one = d.quantize(1.0);

        // take the scratch buffers out so the iteration helpers can
        // borrow `self` (graph, value streams, constants) immutably
        let mut cur = std::mem::take(&mut self.cur);
        let mut nxt = std::mem::take(&mut self.nxt);
        let mut scaling = std::mem::take(&mut self.scaling);
        let mut heaps = std::mem::take(&mut self.heaps);
        let mut merged = std::mem::take(&mut self.merged);

        let top_k = cfg.top_k.filter(|&kk| kk >= 1);
        let num_shards = self.graph.sharded.num_shards();
        if top_k.is_some() {
            let kk = top_k.unwrap();
            heaps.resize_with(num_shards, || LaneHeaps::new(kk, k));
            heaps.truncate(num_shards);
            for h in &mut heaps {
                h.reset(kk, k);
            }
        }

        cur.clear();
        match resume {
            // resume mid-ladder from a previous rung's re-quantized scores
            Some(scores) => {
                assert_eq!(scores.len(), n * k, "resume scores must be n·κ vertex-major");
                cur.extend_from_slice(scores);
            }
            // P₁ ← V̄ : score 1 on each lane's personalization vertex
            None => {
                cur.resize(n * k, z);
                for (lane, &v) in personalization.iter().enumerate() {
                    cur[v as usize * k + lane] = one;
                }
            }
        }
        // the next buffer is fully overwritten by each sweep; only its
        // length matters here
        nxt.resize(n * k, z);
        scaling.clear();
        scaling.resize(k, z);

        let mut update_norms = Vec::with_capacity(cfg.max_iterations);
        let mut iterations = 0usize;

        let stop = match self.executor {
            Executor::Fused => self.iterate_fused(
                &d,
                &mut cur,
                &mut nxt,
                &mut scaling,
                personalization,
                k,
                cfg,
                stall_ratio,
                &mut update_norms,
                &mut iterations,
                top_k.map(|_| (&mut heaps[..], &mut merged)),
            ),
            Executor::Unfused | Executor::UnfusedScoped => self.iterate_unfused(
                &d,
                &mut cur,
                &mut nxt,
                &mut scaling,
                personalization,
                k,
                cfg,
                stall_ratio,
                &mut update_norms,
                &mut iterations,
            ),
        };

        self.cur = cur;
        self.nxt = nxt;
        self.scaling = scaling;
        let topk = top_k.map(|kk| {
            if self.executor == Executor::Fused && iterations > 0 {
                // the merged heaps of the final iteration ARE the ranking
                // (bit-identical to dense extraction — see spmv::topk)
                let saved_per_shard: Vec<u64> =
                    heaps.iter().map(|h| h.skipped_words()).collect();
                RankedLanes {
                    k: kk,
                    lanes: merged
                        .lanes
                        .iter()
                        .map(|c| c.iter().map(|c| (c.vertex, d.to_f64(c.word))).collect())
                        .collect(),
                    writeback_words_saved: saved_per_shard.iter().sum(),
                    saved_per_shard,
                }
            } else {
                // unfused executors (and zero-iteration runs, where no
                // sweep ever fed the heaps) extract densely from the final
                // scores — same word order, no pruning model
                dense_ranked(&d, &self.cur[..n * k], k, kk, num_shards)
            }
        });
        self.heaps = heaps;
        self.merged = merged;
        (stop, PprRun { scores: &self.cur[..n * k], lanes: k, iterations, update_norms, topk })
    }

    /// The fused executor: one sweep per iteration. Each shard scatters
    /// `X·P_t` into its slice of the next buffer and applies Eq. 1, the
    /// norm partial and the next dangling partial in the scatter's clamp
    /// epilogue; the buffers then swap. Dangling partials enter the loop
    /// from one standalone scan of the initial scores (the only time the
    /// dangling rows are visited outside the fused sweep).
    ///
    /// In top-K-native mode (`topk`), each shard's candidate heaps ride
    /// inside the same epilogue; at iteration end the heaps are merged
    /// into the global per-lane top-K and the merged K-th value becomes
    /// every shard's write-back pruning threshold for the next iteration.
    /// The sweep's arithmetic is untouched, so scores, norms and stop
    /// decisions are bit-identical with `topk = None`.
    #[allow(clippy::too_many_arguments)]
    fn iterate_fused(
        &self,
        d: &D,
        cur: &mut Vec<D::Word>,
        nxt: &mut Vec<D::Word>,
        scaling: &mut [D::Word],
        personalization: &[VertexId],
        k: usize,
        cfg: &PprConfig,
        stall_ratio: Option<f64>,
        update_norms: &mut Vec<f64>,
        iterations: &mut usize,
        mut topk: Option<(&mut [LaneHeaps<D::Word>], &mut MergedTopK<D::Word>)>,
    ) -> SegmentStop {
        let mut partials = self.dangling_partials(d, cur, k, false);
        let mut prev_norm: Option<f64> = None;
        let mut slow = 0u32;
        for _ in 0..cfg.max_iterations {
            self.fold_scaling(d, &partials, k, scaling);
            if let Some((heaps, _)) = topk.as_mut() {
                // heaps rebuild each iteration (every vertex re-observed);
                // the thresholds of the last merge persist for pruning
                for h in heaps.iter_mut() {
                    h.begin_iteration();
                }
            }
            let results = self.fused_sweep(
                d,
                cur,
                nxt,
                scaling,
                personalization,
                k,
                topk.as_mut().map(|(h, _)| &mut **h),
            );
            let mut norm_sq = 0.0f64;
            partials.clear();
            for (ns, acc) in results {
                // fold the per-shard norm partials in shard order, same
                // grouping as the unfused update sweep
                norm_sq += ns;
                partials.push(acc);
            }
            std::mem::swap(cur, nxt);
            *iterations += 1;
            if let Some((heaps, merged)) = topk.as_mut() {
                // merge BEFORE any stop decision so the final iteration's
                // global top-K is always in `merged`
                merge_shard_heaps(d, heaps, merged);
            }
            let norm = (norm_sq / k as f64).sqrt();
            update_norms.push(norm);
            // a norm of exactly 0 on a laddered (non-final) rung means the
            // datapath reached its quantization fixed point: nothing more
            // can improve here, so escalate rather than report convergence
            if stall_ratio.is_some() && norm == 0.0 {
                return SegmentStop::Stalled;
            }
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    return SegmentStop::Converged;
                }
            }
            if let Some(ratio) = stall_ratio {
                // two consecutive slow iterations, so a single transient
                // (mass concentrating onto a hub can briefly lift the
                // 2-norm) does not escalate prematurely
                if prev_norm.is_some_and(|prev| norm > prev * ratio) {
                    slow += 1;
                    if slow >= 2 {
                        return SegmentStop::Stalled;
                    }
                } else {
                    slow = 0;
                }
                prev_norm = Some(norm);
            }
        }
        SegmentStop::Budget
    }

    /// The three-sweep executor (the pre-fusion engine): dangling scan,
    /// sharded scatter into `nxt` (P₂), Eq. 1 update back into `cur`.
    #[allow(clippy::too_many_arguments)]
    fn iterate_unfused(
        &self,
        d: &D,
        cur: &mut Vec<D::Word>,
        nxt: &mut Vec<D::Word>,
        scaling: &mut [D::Word],
        personalization: &[VertexId],
        k: usize,
        cfg: &PprConfig,
        stall_ratio: Option<f64>,
        update_norms: &mut Vec<f64>,
        iterations: &mut usize,
    ) -> SegmentStop {
        let scoped = self.executor == Executor::UnfusedScoped;
        let mut prev_norm: Option<f64> = None;
        let mut slow = 0u32;
        for _ in 0..cfg.max_iterations {
            // scaling_vec ← (α/|V|) · (d̄ · P₁) — per lane (Alg. 1 line 6),
            // the dangling scan sharded by destination range
            let partials = self.dangling_partials(d, cur, k, scoped);
            self.fold_scaling(d, &partials, k, scaling);

            // P₂ ← X · P₁ (Alg. 2) — one scatter worker per shard, each
            // writing its own destination slice (see spmv::shard)
            crate::spmv::shard::sharded_edge_sweep(
                d,
                &self.graph.sharded,
                &self.vals,
                k,
                cur,
                nxt,
                scoped,
            );

            // P₁ ← α·P₂ + scaling + (1−α)·V̄, tracking the update norm,
            // sharded over the same disjoint destination ranges
            let norm_sq =
                self.update_sweep(d, cur, nxt, scaling, personalization, k, scoped);

            *iterations += 1;
            let norm = (norm_sq / k as f64).sqrt();
            update_norms.push(norm);
            // a norm of exactly 0 on a laddered (non-final) rung means the
            // datapath reached its quantization fixed point: nothing more
            // can improve here, so escalate rather than report convergence
            if stall_ratio.is_some() && norm == 0.0 {
                return SegmentStop::Stalled;
            }
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    return SegmentStop::Converged;
                }
            }
            if let Some(ratio) = stall_ratio {
                // two consecutive slow iterations, so a single transient
                // (mass concentrating onto a hub can briefly lift the
                // 2-norm) does not escalate prematurely
                if prev_norm.is_some_and(|prev| norm > prev * ratio) {
                    slow += 1;
                    if slow >= 2 {
                        return SegmentStop::Stalled;
                    }
                } else {
                    slow = 0;
                }
                prev_norm = Some(norm);
            }
        }
        SegmentStop::Budget
    }

    /// Per-shard dangling partial sums of `p` (ascending vertex order
    /// within each shard — the same per-lane add sequence as the
    /// single-stream scan restricted to each range).
    fn dangling_partials(
        &self,
        d: &D,
        p: &[D::Word],
        k: usize,
        scoped: bool,
    ) -> Vec<Vec<D::Word>> {
        let shards = &self.graph.sharded.shards;
        let serial = shards.len() == 1
            || self.graph.dangling_idx.len() * k < PARALLEL_WORK_PER_SHARD * shards.len();
        fan_out_mode(shards.iter().collect(), serial, scoped, |sh| {
            dangling_partial(d, &sh.dangling_idx, p, k)
        })
    }

    /// Fold per-shard dangling partials (in shard order) and scale by
    /// α/|V| into the per-lane scaling vector — shared by both executors
    /// so the word sequence cannot diverge.
    fn fold_scaling(&self, d: &D, partials: &[Vec<D::Word>], k: usize, scaling: &mut [D::Word]) {
        let mut it = partials.iter();
        let first = it.next().expect("at least one shard");
        let mut total = first.clone();
        for part in it {
            for lane in 0..k {
                total[lane] = d.add(total[lane], part[lane]);
            }
        }
        for lane in 0..k {
            scaling[lane] = d.mul(self.alpha_over_v, total[lane]);
        }
    }

    /// One fused sweep: per shard, scatter + Eq. 1 epilogue into the
    /// shard's disjoint slice of `nxt`; returns `(norm_sq partial,
    /// dangling partial)` per shard in shard order.
    fn fused_sweep(
        &self,
        d: &D,
        cur: &[D::Word],
        nxt: &mut [D::Word],
        scaling: &[D::Word],
        personalization: &[VertexId],
        k: usize,
        heaps: Option<&mut [LaneHeaps<D::Word>]>,
    ) -> Vec<(f64, Vec<D::Word>)> {
        let shards = &self.graph.sharded.shards;
        let n = self.graph.num_vertices;
        let upd: FusedUpdate<'_, D> = FusedUpdate {
            scaling,
            personalization,
            alpha: self.alpha,
            one_minus_alpha: self.one_minus_alpha,
        };
        if shards.len() == 1 {
            let sh = &shards[0];
            let mut acc = vec![d.zero(); k];
            let norm = scatter_fused(
                d,
                &sh.x,
                &sh.y,
                &self.vals[0],
                k,
                sh.dst_start,
                cur,
                nxt,
                &upd,
                &sh.dangling_idx,
                &mut acc,
                heaps.map(|h| &mut h[0]),
            );
            return vec![(norm, acc)];
        }
        // split the next buffer into the shards' disjoint destination
        // slices — the fused sweep's only writes
        let mut slices: Vec<&mut [D::Word]> = Vec::with_capacity(shards.len());
        let mut rest = nxt;
        for sh in shards {
            let (head, tail) = rest.split_at_mut((sh.dst_end - sh.dst_start) * k);
            slices.push(head);
            rest = tail;
        }
        debug_assert!(rest.is_empty());
        // each shard's heap state travels with its worker (one heap per
        // shard = one candidate unit per HBM pseudo-channel)
        let heap_slots: Vec<Option<&mut LaneHeaps<D::Word>>> = match heaps {
            Some(hs) => hs.iter_mut().map(Some).collect(),
            None => shards.iter().map(|_| None).collect(),
        };
        // work per shard = edges (scatter) + vertices (epilogue), × lanes
        let serial =
            (self.graph.sharded.num_edges + n) * k < PARALLEL_WORK_PER_SHARD * shards.len();
        let work: Vec<_> =
            shards.iter().zip(self.vals.iter()).zip(slices).zip(heap_slots).collect();
        fan_out(work, serial, |(((sh, svals), slice), heap)| {
            let mut acc = vec![d.zero(); k];
            let norm = scatter_fused(
                d,
                &sh.x,
                &sh.y,
                svals,
                k,
                sh.dst_start,
                cur,
                slice,
                &upd,
                &sh.dangling_idx,
                &mut acc,
                heap,
            );
            (norm, acc)
        })
    }

    /// The unfused update sweep, one worker per shard over its
    /// destination slice; returns the summed squared update norm
    /// (partials folded in shard order, so the norm is deterministic for
    /// a given shard count).
    #[allow(clippy::too_many_arguments)]
    fn update_sweep(
        &self,
        d: &D,
        p1: &mut [D::Word],
        p2: &[D::Word],
        scaling: &[D::Word],
        personalization: &[VertexId],
        k: usize,
        scoped: bool,
    ) -> f64 {
        let shards = &self.graph.sharded.shards;
        let alpha = self.alpha;
        let oma = self.one_minus_alpha;
        let n = self.graph.num_vertices;
        if shards.len() == 1 {
            return update_range(d, 0, n, k, p1, p2, scaling, personalization, alpha, oma);
        }
        // split P₁ into the shards' disjoint destination slices
        let mut slices: Vec<&mut [D::Word]> = Vec::with_capacity(shards.len());
        let mut rest = p1;
        for sh in shards {
            let (head, tail) = rest.split_at_mut((sh.dst_end - sh.dst_start) * k);
            slices.push(head);
            rest = tail;
        }
        let serial = n * k < PARALLEL_WORK_PER_SHARD * shards.len();
        let work: Vec<_> = shards.iter().zip(slices).collect();
        let partials = fan_out_mode(work, serial, scoped, |(sh, p1s)| {
            let p2s = &p2[sh.dst_start * k..sh.dst_end * k];
            let (lo, hi) = (sh.dst_start, sh.dst_end);
            update_range(d, lo, hi, k, p1s, p2s, scaling, personalization, alpha, oma)
        });
        // fold the per-shard norm partials in shard order (deterministic
        // for a given shard count; see the module docs on the norm caveat)
        partials.into_iter().sum()
    }

    /// Run a whole request list by splitting it into κ-batches; returns one
    /// dense score vector per request (the host-facing result shape). The
    /// trailing batch runs partial instead of padding with repeated lanes.
    /// Lanes are extracted with chunked copies straight out of the scratch
    /// buffer — no intermediate `PprOutput` allocation per batch.
    pub fn run_requests(&mut self, requests: &[VertexId], cfg: &PprConfig) -> Vec<Vec<D::Word>> {
        let mut out = Vec::with_capacity(requests.len());
        for batch in requests.chunks(self.kappa) {
            let run = self.run_scratch(batch, cfg);
            for lane in 0..run.lanes {
                out.push(copy_lane(run.scores, run.lanes, lane));
            }
        }
        out
    }
}

/// Per-lane sums of `p1` over one shard's dangling vertices, in ascending
/// vertex order (the same per-lane add sequence as the single-stream scan
/// restricted to this range).
fn dangling_partial<D: Datapath>(
    d: &D,
    dangling_idx: &[VertexId],
    p1: &[D::Word],
    k: usize,
) -> Vec<D::Word> {
    let mut acc = vec![d.zero(); k];
    for &dv in dangling_idx {
        let row = dv as usize * k;
        for lane in 0..k {
            acc[lane] = d.add(acc[lane], p1[row + lane]);
        }
    }
    acc
}

/// Apply Eq. 1's affine update to destinations `[lo, hi)`; `p1`/`p2` are
/// the matching slices (`p1[0]` is vertex `lo`). Returns the partial
/// squared update norm.
#[allow(clippy::too_many_arguments)]
fn update_range<D: Datapath>(
    d: &D,
    lo: usize,
    hi: usize,
    k: usize,
    p1: &mut [D::Word],
    p2: &[D::Word],
    scaling: &[D::Word],
    personalization: &[VertexId],
    alpha: D::Word,
    one_minus_alpha: D::Word,
) -> f64 {
    debug_assert_eq!(p1.len(), (hi - lo) * k);
    debug_assert_eq!(p2.len(), (hi - lo) * k);
    let mut norm_sq = 0.0f64;
    for v in lo..hi {
        let row = (v - lo) * k;
        for lane in 0..k {
            let mut x = d.mul(alpha, p2[row + lane]);
            x = d.add(x, scaling[lane]);
            if personalization[lane] as usize == v {
                x = d.add(x, one_minus_alpha);
            }
            let delta = d.abs_diff_f64(x, p1[row + lane]);
            norm_sq += delta * delta;
            p1[row + lane] = x;
        }
    }
    norm_sq
}

/// Dense top-K extraction from a vertex-major score block, in word space
/// through the crate's single selection kernel — the fallback the unfused
/// executors (and zero-iteration runs) use when `top_k` is requested.
/// `cmp_words` agrees with `to_f64`, so the ranking is identical to the
/// streaming heaps'; no sweep was instrumented, so the pruning ledger is
/// zero.
fn dense_ranked<D: Datapath>(
    d: &D,
    scores: &[D::Word],
    lanes: usize,
    k: usize,
    num_shards: usize,
) -> RankedLanes {
    let n = scores.len() / lanes.max(1);
    let mut out = Vec::with_capacity(lanes);
    for lane in 0..lanes {
        let idx = crate::metrics::top_n_by(n, k, |a, b| {
            d.cmp_words(scores[a * lanes + lane], scores[b * lanes + lane])
        });
        out.push(
            idx.into_iter()
                .map(|v| (v as VertexId, d.to_f64(scores[v * lanes + lane])))
                .collect(),
        );
    }
    RankedLanes {
        k,
        lanes: out,
        writeback_words_saved: 0,
        saved_per_shard: vec![0; num_shards],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::ppr::reference;
    use crate::spmv::datapath::{FixedPath, FloatPath};

    fn ring(n: usize) -> Graph {
        Graph::new(n, (0..n as VertexId).map(|i| (i, (i + 1) % n as VertexId)).collect())
    }

    #[test]
    fn scores_sum_to_one_ring() {
        // ring has no dangling vertices; PPR mass is conserved at 1
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let out = engine.run(&[0, 5, 9, 13], &PprConfig { max_iterations: 30, ..Default::default() });
        for lane in 0..4 {
            let sum: f64 = out.lane(lane).iter().map(|&w| d.fmt.to_f64(w)).sum();
            assert!((sum - 1.0).abs() < 1e-4, "lane {lane}: {sum}");
        }
    }

    #[test]
    fn float_path_matches_f64_reference() {
        let g = crate::graph::generators::erdos_renyi(200, 0.04, 31);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 2, 0.85);
        let cfg = PprConfig { max_iterations: 20, ..Default::default() };
        let out = engine.run(&[3, 7], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        for (lane, &pv) in [3u32, 7u32].iter().enumerate() {
            let truth = reference::ppr_f64(&coo, pv, 0.85, 20, None);
            let got = out.lane(lane);
            for v in 0..200 {
                assert!(
                    (got[v] as f64 - truth.scores[v]).abs() < 1e-4,
                    "lane {lane} vertex {v}: {} vs {}",
                    got[v],
                    truth.scores[v]
                );
            }
        }
    }

    #[test]
    fn fixed_close_to_reference_at_26_bits() {
        let g = crate::graph::generators::holme_kim(300, 4, 0.2, 17);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 1, 0.85);
        let cfg = PprConfig { max_iterations: 15, ..Default::default() };
        let out = engine.run(&[10], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let truth = reference::ppr_f64(&coo, 10, 0.85, 15, None);
        let got = out.lane(0);
        for v in 0..300 {
            assert!(
                (d.fmt.to_f64(got[v]) - truth.scores[v]).abs() < 1e-3,
                "vertex {v}"
            );
        }
    }

    #[test]
    fn personalization_vertex_ranks_first() {
        let g = crate::graph::generators::watts_strogatz(128, 6, 0.2, 3);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg.clone(), 2, 0.85);
        let out = engine.run(&[42, 100], &PprConfig::paper_timed());
        for (lane, &pv) in [42usize, 100usize].iter().enumerate() {
            let lane_scores = out.lane(lane);
            let best = (0..128).max_by_key(|&v| lane_scores[v]).unwrap();
            assert_eq!(best, pv, "lane {lane}");
        }
    }

    #[test]
    fn early_exit_on_threshold() {
        let g = ring(32);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let cfg = PprConfig {
            max_iterations: 100,
            convergence_threshold: Some(1e-4),
            ..Default::default()
        };
        let out = engine.run(&[0], &cfg);
        assert!(out.iterations < 100, "should converge early, ran {}", out.iterations);
        assert!(*out.update_norms.last().unwrap() < 1e-4);
    }

    #[test]
    fn run_requests_covers_all() {
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(22);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let reqs: Vec<VertexId> = (0..10).collect();
        // a directed ring pushes an α^t mass spike forward while
        // unconverged, so run enough iterations that α^t < 1−α
        let cfg = PprConfig { max_iterations: 50, ..Default::default() };
        let outs = engine.run_requests(&reqs, &cfg);
        assert_eq!(outs.len(), 10);
        for (i, o) in outs.iter().enumerate() {
            let best = (0..64).max_by_key(|&v| o[v]).unwrap();
            assert_eq!(best, i, "request {i} should rank itself first");
        }
    }

    #[test]
    fn partial_batch_lane_bit_identical_to_full() {
        let g = crate::graph::generators::holme_kim(200, 4, 0.25, 9);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg, 4, 0.85);
        let cfg = PprConfig { max_iterations: 12, ..Default::default() };
        let full = engine.run(&[5, 9, 33, 71], &cfg);
        let partial = engine.run(&[5, 9], &cfg);
        assert_eq!(partial.lanes, 2);
        assert_eq!(full.lanes, 4);
        // lanes never interact, so a 2-lane batch reproduces the same words
        assert_eq!(partial.lane(0), full.lane(0));
        assert_eq!(partial.lane(1), full.lane(1));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn lane_bounds_checked() {
        let g = ring(16);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let d = FixedPath::paper(20);
        let mut engine = BatchedPpr::new(d, pg, 4, 0.85);
        let out = engine.run(&[1, 2], &PprConfig { max_iterations: 2, ..Default::default() });
        let _ = out.lane(2); // run carried 2 lanes; lane 2 must panic
    }

    #[test]
    fn dangling_mass_redistributed() {
        // star into a sink: vertex 0..3 -> 4, vertex 4 dangling
        let g = Graph::new(5, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let out = engine.run(&[0], &PprConfig { max_iterations: 50, ..Default::default() });
        let s = out.lane(0);
        // sink collects mass, but dangling redistribution keeps the total ≈ 1
        let total: f32 = s.iter().sum();
        assert!((total - 1.0).abs() < 0.02, "total {total}");
        assert!(s[4] > s[1], "sink should outrank non-personalized leaves");
    }

    #[test]
    fn threaded_sweeps_bit_identical_to_single_shard() {
        // big enough that the sweeps take the pooled path (edges, |V|·k
        // and |dangling|·k all ≥ 4 shards × PARALLEL_WORK_PER_SHARD):
        // half the vertices source edges, half are dangling
        let n = 12_000usize;
        let k = 6usize;
        let mut rng = crate::util::rng::Xoshiro256::seeded(99);
        let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
        for s in 0..(n / 2) as VertexId {
            for _ in 0..6 {
                let d = rng.next_index(n) as VertexId;
                if d != s {
                    edges.push((s, d));
                }
            }
        }
        let g = Graph::new(n, edges);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        assert!(coo.num_edges() >= 1 << 15);
        let d = FixedPath::paper(26);
        let cfg = PprConfig { max_iterations: 3, ..Default::default() };
        let pers: Vec<VertexId> = vec![1, 2, 3, 4, 5, 6];
        let pg1 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 1));
        let base = BatchedPpr::new(d, pg1, k, 0.85).run(&pers, &cfg);
        let pg4 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 4));
        let out = BatchedPpr::new(d, pg4, k, 0.85).run(&pers, &cfg);
        assert_eq!(base.scores, out.scores);
    }

    #[test]
    fn sharded_engine_bit_identical_to_single_shard_fixed() {
        // the whole Alg. 1 loop — dangling scan, edge sweep, update — must
        // produce identical words for any shard count on the fixed path
        let g = crate::graph::generators::holme_kim(240, 4, 0.25, 13);
        let d = FixedPath::paper(24);
        let cfg = PprConfig { max_iterations: 10, ..Default::default() };
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let pg1 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 1));
        let base = BatchedPpr::new(d, pg1, 3, 0.85).run(&[2, 7, 11], &cfg);
        for shards in [2usize, 3, 5] {
            let pgs = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, shards));
            let mut engine = BatchedPpr::new(d, pgs, 3, 0.85);
            assert_eq!(engine.num_shards(), shards);
            let out = engine.run(&[2, 7, 11], &cfg);
            assert_eq!(out.scores, base.scores, "shards={shards}");
            assert_eq!(out.update_norms.len(), base.update_norms.len());
        }
    }

    #[test]
    fn fused_matches_unfused_scores_and_norms() {
        // fused ≡ unfused bit-exactly — scores AND the f64 norms — for
        // both datapaths at a fixed shard count
        let g = crate::graph::generators::holme_kim(260, 4, 0.3, 29);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let cfg = PprConfig { max_iterations: 9, ..Default::default() };
        for shards in [1usize, 3] {
            let pg = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, shards));
            let d = FixedPath::paper(24);
            let fused = BatchedPpr::new(d, pg.clone(), 3, 0.85).run(&[2, 8, 21], &cfg);
            let unfused = BatchedPpr::new(d, pg.clone(), 3, 0.85)
                .with_executor(Executor::Unfused)
                .run(&[2, 8, 21], &cfg);
            assert_eq!(fused.scores, unfused.scores, "fixed shards={shards}");
            assert_eq!(fused.update_norms, unfused.update_norms, "norms shards={shards}");

            let fused_f = BatchedPpr::new(FloatPath, pg.clone(), 3, 0.85).run(&[2, 8, 21], &cfg);
            let unfused_f = BatchedPpr::new(FloatPath, pg.clone(), 3, 0.85)
                .with_executor(Executor::UnfusedScoped)
                .run(&[2, 8, 21], &cfg);
            assert_eq!(fused_f.scores, unfused_f.scores, "float shards={shards}");
            assert_eq!(fused_f.update_norms, unfused_f.update_norms);
        }
    }

    #[test]
    fn fused_early_exit_matches_unfused() {
        // identical norms → identical early-exit iteration
        let g = ring(48);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let cfg = PprConfig {
            max_iterations: 100,
            convergence_threshold: Some(1e-4),
            ..Default::default()
        };
        let fused = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85).run(&[0], &cfg);
        let unfused = BatchedPpr::new(FloatPath, pg, 1, 0.85)
            .with_executor(Executor::Unfused)
            .run(&[0], &cfg);
        assert_eq!(fused.iterations, unfused.iterations);
        assert_eq!(fused.scores, unfused.scores);
    }

    #[test]
    fn scratch_reuse_across_runs_is_bit_stable() {
        // back-to-back runs on one engine (reused scratch) must equal runs
        // on fresh engines, across different lane counts
        let g = crate::graph::generators::erdos_renyi(180, 0.04, 7);
        let pg = Arc::new(PreparedGraph::new_sharded(&g, 8, 2));
        let d = FixedPath::paper(22);
        let cfg = PprConfig { max_iterations: 8, ..Default::default() };
        let mut reused = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let a1 = reused.run(&[1, 2, 3, 4], &cfg);
        let a2 = reused.run(&[5], &cfg);
        let a3 = reused.run(&[1, 2, 3, 4], &cfg);
        let b1 = BatchedPpr::new(d, pg.clone(), 4, 0.85).run(&[1, 2, 3, 4], &cfg);
        let b2 = BatchedPpr::new(d, pg, 4, 0.85).run(&[5], &cfg);
        assert_eq!(a1.scores, b1.scores);
        assert_eq!(a2.scores, b2.scores);
        assert_eq!(a3.scores, b1.scores, "third run must not see stale scratch");
    }

    #[test]
    fn run_scratch_borrows_final_scores() {
        let g = ring(32);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg, 2, 0.85);
        let cfg = PprConfig { max_iterations: 5, ..Default::default() };
        let owned = engine.run(&[3, 9], &cfg);
        let run = engine.run_scratch(&[3, 9], &cfg);
        assert_eq!(run.lanes, 2);
        assert_eq!(run.iterations, 5);
        assert_eq!(run.scores, owned.scores.as_slice());
        assert_eq!(run.update_norms, owned.update_norms);
    }

    #[test]
    fn copy_lane_strided_and_single_lane() {
        let scores = vec![10u64, 11, 20, 21, 30, 31];
        assert_eq!(copy_lane(&scores, 2, 0), vec![10, 20, 30]);
        assert_eq!(copy_lane(&scores, 2, 1), vec![11, 21, 31]);
        let single = vec![7u64, 8, 9];
        assert_eq!(copy_lane(&single, 1, 0), single);
    }

    #[test]
    fn shared_value_streams_bit_identical_to_inline_quantization() {
        let g = crate::graph::generators::holme_kim(200, 4, 0.25, 21);
        let pg = Arc::new(PreparedGraph::new_sharded(&g, 8, 3));
        let d = FixedPath::paper(22);
        let cfg = PprConfig { max_iterations: 8, ..Default::default() };
        let vals = Arc::new(pg.sharded.quantize_values_for(&d));
        let a = BatchedPpr::new(d, pg.clone(), 2, 0.85).run(&[3, 9], &cfg);
        let b = BatchedPpr::with_shared_values(d, pg.clone(), vals.clone(), 2, 0.85)
            .run(&[3, 9], &cfg);
        assert_eq!(a.scores, b.scores, "shared streams must not change a single word");
        assert_eq!(a.update_norms, b.update_norms);
        // float datapath too
        let fvals = Arc::new(pg.sharded.quantize_values_for(&FloatPath));
        let af = BatchedPpr::new(FloatPath, pg.clone(), 2, 0.85).run(&[3, 9], &cfg);
        let bf = BatchedPpr::with_shared_values(FloatPath, pg, fvals, 2, 0.85).run(&[3, 9], &cfg);
        assert_eq!(af.scores, bf.scores);
    }

    #[test]
    fn run_segment_resume_continues_bit_exactly() {
        // 10 iterations in one go ≡ 4 + resume(6) at the same rung, for
        // both executors — the invariant the ladder's hot-switch rests on
        let g = crate::graph::generators::holme_kim(220, 4, 0.25, 41);
        let pg = Arc::new(PreparedGraph::new_sharded(&g, 8, 2));
        let d = FixedPath::paper(24);
        for executor in [Executor::Fused, Executor::Unfused] {
            let full = BatchedPpr::new(d, pg.clone(), 2, 0.85)
                .with_executor(executor)
                .run(&[1, 5], &PprConfig { max_iterations: 10, ..Default::default() });
            let mut engine =
                BatchedPpr::new(d, pg.clone(), 2, 0.85).with_executor(executor);
            let cfg4 = PprConfig { max_iterations: 4, ..Default::default() };
            let (stop, seg) = engine.run_segment(&[1, 5], &cfg4, None, None);
            assert_eq!(stop, SegmentStop::Budget);
            assert_eq!(seg.iterations, 4);
            let mid = seg.scores.to_vec();
            let mut norms = seg.update_norms.clone();
            let cfg6 = PprConfig { max_iterations: 6, ..Default::default() };
            let (stop, seg) = engine.run_segment(&[1, 5], &cfg6, Some(&mid), None);
            assert_eq!(stop, SegmentStop::Budget);
            assert_eq!(seg.scores, full.scores.as_slice(), "{executor:?}");
            norms.extend_from_slice(&seg.update_norms);
            assert_eq!(norms, full.update_norms, "{executor:?}");
        }
    }

    #[test]
    fn run_segment_stalls_at_the_quantization_floor() {
        // a narrow rung cannot push its update norm below its ulp floor:
        // with a far tighter threshold the segment must report Stalled
        // (never Converged), and stop well before a generous budget
        let g = crate::graph::generators::holme_kim(300, 4, 0.25, 17);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(12);
        let mut engine = BatchedPpr::new(d, pg, 1, 0.85);
        // threshold 0 is unreachable (norms are non-negative), so the only
        // ways out are a detected stall or the budget; Q1.11 arithmetic
        // must plateau (or hit an exact fixed point) long before 400
        let cfg = PprConfig {
            max_iterations: 400,
            convergence_threshold: Some(0.0),
            ..Default::default()
        };
        let (stop, seg) = engine.run_segment(&[7], &cfg, None, Some(0.95));
        assert_eq!(stop, SegmentStop::Stalled);
        assert!(seg.iterations < 400, "stall detected before the budget ran out");
    }

    #[test]
    fn run_segment_without_stall_matches_run_scratch() {
        let g = ring(48);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let cfg = PprConfig {
            max_iterations: 60,
            convergence_threshold: Some(1e-5),
            ..Default::default()
        };
        let base = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85).run(&[0], &cfg);
        let mut engine = BatchedPpr::new(FloatPath, pg, 1, 0.85);
        let (stop, seg) = engine.run_segment(&[0], &cfg, None, None);
        assert_eq!(stop, SegmentStop::Converged);
        assert_eq!(seg.scores, base.scores.as_slice());
        assert_eq!(seg.update_norms, base.update_norms);
    }

    fn ranked_from_dense<D: Datapath>(d: &D, out: &PprOutput<D::Word>, k: usize) -> Vec<Vec<(VertexId, f64)>> {
        let n = out.scores.len() / out.lanes;
        (0..out.lanes)
            .map(|lane| {
                crate::metrics::top_n_by(n, k, |a, b| {
                    d.cmp_words(out.scores[a * out.lanes + lane], out.scores[b * out.lanes + lane])
                })
                .into_iter()
                .map(|v| (v as VertexId, d.to_f64(out.scores[v * out.lanes + lane])))
                .collect()
            })
            .collect()
    }

    #[test]
    fn topk_native_matches_dense_extraction() {
        // the streaming heaps must reproduce the dense ranking exactly —
        // vertices AND scores — at every shard count, and leave the dense
        // scores / norms / iteration counts bit-unchanged
        let g = crate::graph::generators::holme_kim(260, 4, 0.3, 29);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let cfg_plain = PprConfig { max_iterations: 9, ..Default::default() };
        for shards in [1usize, 3] {
            let pg = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, shards));
            let d = FixedPath::paper(24);
            let plain = BatchedPpr::new(d, pg.clone(), 3, 0.85).run(&[2, 8, 21], &cfg_plain);
            for kk in [5usize, 40, 500] {
                let cfg = PprConfig { top_k: Some(kk), ..cfg_plain };
                let out = BatchedPpr::new(d, pg.clone(), 3, 0.85).run(&[2, 8, 21], &cfg);
                assert_eq!(out.scores, plain.scores, "scores unchanged by top-K mode");
                assert_eq!(out.update_norms, plain.update_norms);
                let ranked = out.topk.expect("top_k set");
                assert_eq!(ranked.k, kk);
                assert_eq!(ranked.saved_per_shard.len(), shards);
                assert_eq!(ranked.lanes, ranked_from_dense(&d, &plain, kk), "shards={shards} k={kk}");
            }
        }
    }

    #[test]
    fn topk_counts_prunable_writeback_words() {
        // after the first merge installs thresholds, later iterations must
        // find sub-θ words (most of a power-law graph sits far below the
        // 10th-ranked score), and the per-shard ledger must sum to the total
        let g = crate::graph::generators::holme_kim(300, 4, 0.25, 17);
        let pg = Arc::new(PreparedGraph::new_sharded(&g, 8, 3));
        let d = FixedPath::paper(26);
        let cfg = PprConfig { max_iterations: 12, top_k: Some(10), ..Default::default() };
        let out = BatchedPpr::new(d, pg, 2, 0.85).run(&[1, 7], &cfg);
        let ranked = out.topk.unwrap();
        assert!(ranked.writeback_words_saved > 0, "no prunable words found");
        assert_eq!(
            ranked.saved_per_shard.iter().sum::<u64>(),
            ranked.writeback_words_saved
        );
        // upper bound: (iterations − 1) sweeps could prune, n·κ words each
        assert!(ranked.writeback_words_saved < (12 * 300 * 2) as u64);
    }

    #[test]
    fn topk_unfused_falls_back_to_dense_extraction() {
        let g = crate::graph::generators::erdos_renyi(150, 0.04, 3);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(22);
        let cfg = PprConfig { max_iterations: 8, top_k: Some(7), ..Default::default() };
        let fused = BatchedPpr::new(d, pg.clone(), 2, 0.85).run(&[3, 9], &cfg);
        let unfused = BatchedPpr::new(d, pg, 2, 0.85)
            .with_executor(Executor::Unfused)
            .run(&[3, 9], &cfg);
        let (f, u) = (fused.topk.unwrap(), unfused.topk.unwrap());
        assert_eq!(f.lanes, u.lanes, "identical rankings on both executors");
        assert_eq!(u.writeback_words_saved, 0, "no sweep instrumented → no ledger");
    }

    #[test]
    fn topk_scratch_reseeds_across_runs() {
        // consecutive runs with different K (and a no-topk run in between)
        // must not leak candidates or thresholds across requests
        let g = crate::graph::generators::holme_kim(200, 4, 0.25, 9);
        let pg = Arc::new(PreparedGraph::new_sharded(&g, 8, 2));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg.clone(), 2, 0.85);
        let cfg_a = PprConfig { max_iterations: 8, top_k: Some(20), ..Default::default() };
        let a1 = engine.run(&[5, 9], &cfg_a);
        let _plain = engine.run(&[1, 2], &PprConfig { max_iterations: 8, ..Default::default() });
        let cfg_b = PprConfig { max_iterations: 8, top_k: Some(3), ..Default::default() };
        let b = engine.run(&[5, 9], &cfg_b);
        let a2 = engine.run(&[5, 9], &cfg_a);
        let fresh_b = BatchedPpr::new(d, pg, 2, 0.85).run(&[5, 9], &cfg_b);
        assert_eq!(a1.topk.unwrap().lanes, a2.topk.unwrap().lanes);
        assert_eq!(b.topk.as_ref().unwrap().lanes, fresh_b.topk.as_ref().unwrap().lanes);
        assert_eq!(
            b.topk.unwrap().writeback_words_saved,
            fresh_b.topk.unwrap().writeback_words_saved,
            "the pruning ledger must restart with every run"
        );
    }

    #[test]
    fn executor_labels() {
        assert_eq!(Executor::Fused.label(), "fused");
        assert_eq!(Executor::Unfused.label(), "unfused");
        assert_eq!(Executor::UnfusedScoped.label(), "unfused-scoped");
        let g = ring(8);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let e = BatchedPpr::new(FloatPath, pg, 1, 0.85).with_executor(Executor::Unfused);
        assert_eq!(e.executor(), Executor::Unfused);
    }
}
