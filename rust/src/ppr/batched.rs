//! Alg. 1 — κ-batched Personalized PageRank on the streaming SpMV engine,
//! generic over the arithmetic datapath. This is the bit-accurate software
//! model of the FPGA computation: every multiply, add and quantization
//! happens exactly where the hardware datapath performs it.
//!
//! The engine is **sharded** (DESIGN.md §4): the prepared graph carries
//! one destination-partitioned packet stream per shard, and all three
//! per-iteration sweeps — dangling scan, edge stream, update — fan out
//! across the shards' disjoint destination ranges on scoped threads. With
//! one shard every sweep runs inline and is bit-identical to the original
//! single-stream engine; with many shards the fixed-point datapath's
//! *score words* are still bit-identical every iteration (saturating adds
//! of non-negative values give `min(Σ, max)` under any grouping), while
//! the float datapath may differ in the last ulp of the dangling sum,
//! exactly like a per-CU hardware reduction tree would.
//!
//! One caveat: the reported update norm is an f64 reduction whose
//! grouping follows the shards (deterministic for a fixed shard count,
//! but not identical across shard counts — f64 addition is not
//! associative). A `convergence_threshold` that lands within an ulp of
//! the norm can therefore stop at a different iteration for different
//! shard counts; fixed-iteration runs (the paper's timed configuration)
//! are unaffected.

use super::{PprConfig, PreparedGraph};
use crate::graph::VertexId;
use crate::spmv::shard::{fan_out, PARALLEL_WORK_PER_SHARD};
use crate::spmv::Datapath;
use std::sync::Arc;

/// Result of one batched PPR run.
#[derive(Debug, Clone)]
pub struct PprOutput<W> {
    /// Final scores, `num_vertices × lanes`, vertex-major
    /// (`scores[v*lanes + k]`).
    pub scores: Vec<W>,
    /// Lanes this run carried (≤ the engine's κ for partial batches).
    pub lanes: usize,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Per-iteration Euclidean norm of the update, averaged over lanes
    /// (the convergence signal of Fig. 7).
    pub update_norms: Vec<f64>,
}

impl<W: Copy> PprOutput<W> {
    /// Extract lane `k` as a dense vector. The stride is the run's actual
    /// lane count (partial batches carry fewer lanes than the engine's κ).
    pub fn lane(&self, k: usize) -> Vec<W> {
        assert!(k < self.lanes, "lane {k} out of range (run carried {})", self.lanes);
        self.scores.iter().skip(k).step_by(self.lanes).copied().collect()
    }
}

/// Batched PPR engine bound to a prepared graph and a datapath.
pub struct BatchedPpr<D: Datapath> {
    /// Arithmetic datapath.
    pub datapath: D,
    /// Maximum lanes per pass (a run may carry fewer).
    pub kappa: usize,
    graph: Arc<PreparedGraph>,
    /// Per-shard quantized value streams (the per-CU channel contents).
    vals: Vec<Vec<D::Word>>,
    // quantized constants of Eq. 1
    alpha: D::Word,
    one_minus_alpha: D::Word,
    alpha_over_v: D::Word,
}

impl<D: Datapath> BatchedPpr<D> {
    /// Bind an engine to a prepared graph. `alpha` is quantized once here,
    /// like the synthesized constants of the bitstream; each shard's value
    /// stream is quantized once, like loading the partitions onto their
    /// channels (§4.2).
    pub fn new(datapath: D, graph: Arc<PreparedGraph>, kappa: usize, alpha: f64) -> Self {
        assert!((0.0..1.0).contains(&alpha));
        let vals = graph
            .sharded
            .shards
            .iter()
            .map(|s| s.val.iter().map(|&v| datapath.quantize(v)).collect())
            .collect();
        let alpha_w = datapath.quantize(alpha);
        let one_minus_alpha = datapath.quantize(1.0 - alpha);
        let alpha_over_v = datapath.quantize(alpha / graph.num_vertices as f64);
        Self { datapath, kappa, graph, vals, alpha: alpha_w, one_minus_alpha, alpha_over_v }
    }

    /// Number of shards (parallel compute units) the engine sweeps.
    pub fn num_shards(&self) -> usize {
        self.graph.sharded.num_shards()
    }

    /// Run Alg. 1 for a batch of 1..=κ personalization vertices. Partial
    /// batches are first-class: compute scales with the lanes actually
    /// carried, and each lane is bit-identical to the same lane of any
    /// other batch shape (lanes never interact).
    pub fn run(&mut self, personalization: &[VertexId], cfg: &PprConfig) -> PprOutput<D::Word> {
        let k = personalization.len();
        assert!(
            k >= 1 && k <= self.kappa,
            "batch of {k} lanes outside 1..=κ ({})",
            self.kappa
        );
        let d = self.datapath.clone();
        let n = self.graph.num_vertices;
        let z = d.zero();
        let one = d.quantize(1.0);

        // P₁ ← V̄ : score 1 on each lane's personalization vertex
        let mut p1 = vec![z; n * k];
        for (lane, &v) in personalization.iter().enumerate() {
            p1[v as usize * k + lane] = one;
        }
        let mut p2 = vec![z; n * k];
        let mut scaling = vec![z; k];
        let mut update_norms = Vec::with_capacity(cfg.max_iterations);
        let mut iterations = 0usize;

        for _ in 0..cfg.max_iterations {
            // scaling_vec ← (α/|V|) · (d̄ · P₁) — per lane (Alg. 1 line 6),
            // the dangling scan sharded by destination range
            self.scaling_sweep(&d, &p1, k, &mut scaling);

            // P₂ ← X · P₁ (Alg. 2) — one scatter worker per shard, each
            // writing its own destination slice (see spmv::shard)
            crate::spmv::fast_spmv_sharded(&d, &self.graph.sharded, &self.vals, k, &p1, &mut p2);

            // P₁ ← α·P₂ + scaling + (1−α)·V̄, tracking the update norm,
            // sharded over the same disjoint destination ranges
            let norm_sq = self.update_sweep(&d, &mut p1, &p2, &scaling, personalization, k);

            iterations += 1;
            let norm = (norm_sq / k as f64).sqrt();
            update_norms.push(norm);
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    break;
                }
            }
        }

        PprOutput { scores: p1, lanes: k, iterations, update_norms }
    }

    /// The dangling scan: per-shard partial sums over each shard's
    /// dangling vertices, folded in shard order, then scaled by α/|V|.
    /// One shard reproduces the single-stream scan exactly, and the
    /// sequential small-work path produces the same words as the parallel
    /// one (partials are folded in shard order either way).
    fn scaling_sweep(&self, d: &D, p1: &[D::Word], k: usize, scaling: &mut [D::Word]) {
        let shards = &self.graph.sharded.shards;
        let serial = shards.len() == 1
            || self.graph.dangling_idx.len() * k < PARALLEL_WORK_PER_SHARD * shards.len();
        let partials = fan_out(shards.iter().collect(), serial, |sh| {
            dangling_partial(d, &sh.dangling_idx, p1, k)
        });
        let mut partials = partials.into_iter();
        let mut total = partials.next().expect("at least one shard");
        for part in partials {
            for lane in 0..k {
                total[lane] = d.add(total[lane], part[lane]);
            }
        }
        for lane in 0..k {
            scaling[lane] = d.mul(self.alpha_over_v, total[lane]);
        }
    }

    /// The update sweep, one worker per shard over its destination slice;
    /// returns the summed squared update norm (partials folded in shard
    /// order, so the norm is deterministic for a given shard count).
    fn update_sweep(
        &self,
        d: &D,
        p1: &mut [D::Word],
        p2: &[D::Word],
        scaling: &[D::Word],
        personalization: &[VertexId],
        k: usize,
    ) -> f64 {
        let shards = &self.graph.sharded.shards;
        let alpha = self.alpha;
        let oma = self.one_minus_alpha;
        let n = self.graph.num_vertices;
        if shards.len() == 1 {
            return update_range(d, 0, n, k, p1, p2, scaling, personalization, alpha, oma);
        }
        // split P₁ into the shards' disjoint destination slices
        let mut slices: Vec<&mut [D::Word]> = Vec::with_capacity(shards.len());
        let mut rest = p1;
        for sh in shards {
            let (head, tail) = rest.split_at_mut((sh.dst_end - sh.dst_start) * k);
            slices.push(head);
            rest = tail;
        }
        let serial = n * k < PARALLEL_WORK_PER_SHARD * shards.len();
        let work: Vec<_> = shards.iter().zip(slices).collect();
        let partials = fan_out(work, serial, |(sh, p1s)| {
            let p2s = &p2[sh.dst_start * k..sh.dst_end * k];
            let (lo, hi) = (sh.dst_start, sh.dst_end);
            update_range(d, lo, hi, k, p1s, p2s, scaling, personalization, alpha, oma)
        });
        // fold the per-shard norm partials in shard order (deterministic
        // for a given shard count; see the module docs on the norm caveat)
        partials.into_iter().sum()
    }

    /// Run a whole request list by splitting it into κ-batches; returns one
    /// dense score vector per request (the host-facing result shape). The
    /// trailing batch runs partial instead of padding with repeated lanes.
    pub fn run_requests(&mut self, requests: &[VertexId], cfg: &PprConfig) -> Vec<Vec<D::Word>> {
        let mut out = Vec::with_capacity(requests.len());
        for batch in requests.chunks(self.kappa) {
            let res = self.run(batch, cfg);
            for lane in 0..batch.len() {
                out.push(res.lane(lane));
            }
        }
        out
    }
}

/// Per-lane sums of `p1` over one shard's dangling vertices, in ascending
/// vertex order (the same per-lane add sequence as the single-stream scan
/// restricted to this range).
fn dangling_partial<D: Datapath>(
    d: &D,
    dangling_idx: &[VertexId],
    p1: &[D::Word],
    k: usize,
) -> Vec<D::Word> {
    let mut acc = vec![d.zero(); k];
    for &dv in dangling_idx {
        let row = dv as usize * k;
        for lane in 0..k {
            acc[lane] = d.add(acc[lane], p1[row + lane]);
        }
    }
    acc
}

/// Apply Eq. 1's affine update to destinations `[lo, hi)`; `p1`/`p2` are
/// the matching slices (`p1[0]` is vertex `lo`). Returns the partial
/// squared update norm.
#[allow(clippy::too_many_arguments)]
fn update_range<D: Datapath>(
    d: &D,
    lo: usize,
    hi: usize,
    k: usize,
    p1: &mut [D::Word],
    p2: &[D::Word],
    scaling: &[D::Word],
    personalization: &[VertexId],
    alpha: D::Word,
    one_minus_alpha: D::Word,
) -> f64 {
    debug_assert_eq!(p1.len(), (hi - lo) * k);
    debug_assert_eq!(p2.len(), (hi - lo) * k);
    let mut norm_sq = 0.0f64;
    for v in lo..hi {
        let row = (v - lo) * k;
        for lane in 0..k {
            let mut x = d.mul(alpha, p2[row + lane]);
            x = d.add(x, scaling[lane]);
            if personalization[lane] as usize == v {
                x = d.add(x, one_minus_alpha);
            }
            let delta = d.abs_diff_f64(x, p1[row + lane]);
            norm_sq += delta * delta;
            p1[row + lane] = x;
        }
    }
    norm_sq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::ppr::reference;
    use crate::spmv::datapath::{FixedPath, FloatPath};

    fn ring(n: usize) -> Graph {
        Graph::new(n, (0..n as VertexId).map(|i| (i, (i + 1) % n as VertexId)).collect())
    }

    #[test]
    fn scores_sum_to_one_ring() {
        // ring has no dangling vertices; PPR mass is conserved at 1
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let out = engine.run(&[0, 5, 9, 13], &PprConfig { max_iterations: 30, ..Default::default() });
        for lane in 0..4 {
            let sum: f64 = out.lane(lane).iter().map(|&w| d.fmt.to_f64(w)).sum();
            assert!((sum - 1.0).abs() < 1e-4, "lane {lane}: {sum}");
        }
    }

    #[test]
    fn float_path_matches_f64_reference() {
        let g = crate::graph::generators::erdos_renyi(200, 0.04, 31);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 2, 0.85);
        let cfg = PprConfig { max_iterations: 20, ..Default::default() };
        let out = engine.run(&[3, 7], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        for (lane, &pv) in [3u32, 7u32].iter().enumerate() {
            let truth = reference::ppr_f64(&coo, pv, 0.85, 20, None);
            let got = out.lane(lane);
            for v in 0..200 {
                assert!(
                    (got[v] as f64 - truth.scores[v]).abs() < 1e-4,
                    "lane {lane} vertex {v}: {} vs {}",
                    got[v],
                    truth.scores[v]
                );
            }
        }
    }

    #[test]
    fn fixed_close_to_reference_at_26_bits() {
        let g = crate::graph::generators::holme_kim(300, 4, 0.2, 17);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 1, 0.85);
        let cfg = PprConfig { max_iterations: 15, ..Default::default() };
        let out = engine.run(&[10], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let truth = reference::ppr_f64(&coo, 10, 0.85, 15, None);
        let got = out.lane(0);
        for v in 0..300 {
            assert!(
                (d.fmt.to_f64(got[v]) - truth.scores[v]).abs() < 1e-3,
                "vertex {v}"
            );
        }
    }

    #[test]
    fn personalization_vertex_ranks_first() {
        let g = crate::graph::generators::watts_strogatz(128, 6, 0.2, 3);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg.clone(), 2, 0.85);
        let out = engine.run(&[42, 100], &PprConfig::paper_timed());
        for (lane, &pv) in [42usize, 100usize].iter().enumerate() {
            let lane_scores = out.lane(lane);
            let best = (0..128).max_by_key(|&v| lane_scores[v]).unwrap();
            assert_eq!(best, pv, "lane {lane}");
        }
    }

    #[test]
    fn early_exit_on_threshold() {
        let g = ring(32);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let cfg = PprConfig {
            max_iterations: 100,
            convergence_threshold: Some(1e-4),
            ..Default::default()
        };
        let out = engine.run(&[0], &cfg);
        assert!(out.iterations < 100, "should converge early, ran {}", out.iterations);
        assert!(*out.update_norms.last().unwrap() < 1e-4);
    }

    #[test]
    fn run_requests_covers_all() {
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(22);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let reqs: Vec<VertexId> = (0..10).collect();
        // a directed ring pushes an α^t mass spike forward while
        // unconverged, so run enough iterations that α^t < 1−α
        let cfg = PprConfig { max_iterations: 50, ..Default::default() };
        let outs = engine.run_requests(&reqs, &cfg);
        assert_eq!(outs.len(), 10);
        for (i, o) in outs.iter().enumerate() {
            let best = (0..64).max_by_key(|&v| o[v]).unwrap();
            assert_eq!(best, i, "request {i} should rank itself first");
        }
    }

    #[test]
    fn partial_batch_lane_bit_identical_to_full() {
        let g = crate::graph::generators::holme_kim(200, 4, 0.25, 9);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg, 4, 0.85);
        let cfg = PprConfig { max_iterations: 12, ..Default::default() };
        let full = engine.run(&[5, 9, 33, 71], &cfg);
        let partial = engine.run(&[5, 9], &cfg);
        assert_eq!(partial.lanes, 2);
        assert_eq!(full.lanes, 4);
        // lanes never interact, so a 2-lane batch reproduces the same words
        assert_eq!(partial.lane(0), full.lane(0));
        assert_eq!(partial.lane(1), full.lane(1));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn lane_bounds_checked() {
        let g = ring(16);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let d = FixedPath::paper(20);
        let mut engine = BatchedPpr::new(d, pg, 4, 0.85);
        let out = engine.run(&[1, 2], &PprConfig { max_iterations: 2, ..Default::default() });
        let _ = out.lane(2); // run carried 2 lanes; lane 2 must panic
    }

    #[test]
    fn dangling_mass_redistributed() {
        // star into a sink: vertex 0..3 -> 4, vertex 4 dangling
        let g = Graph::new(5, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let out = engine.run(&[0], &PprConfig { max_iterations: 50, ..Default::default() });
        let s = out.lane(0);
        // sink collects mass, but dangling redistribution keeps the total ≈ 1
        let total: f32 = s.iter().sum();
        assert!((total - 1.0).abs() < 0.02, "total {total}");
        assert!(s[4] > s[1], "sink should outrank non-personalized leaves");
    }

    #[test]
    fn threaded_sweeps_bit_identical_to_single_shard() {
        // big enough that all three sweeps take the scoped-thread path
        // (edges, |V|·k and |dangling|·k all ≥ 4 shards ×
        // PARALLEL_WORK_PER_SHARD): half the vertices source edges, half
        // are dangling
        let n = 12_000usize;
        let k = 6usize;
        let mut rng = crate::util::rng::Xoshiro256::seeded(99);
        let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
        for s in 0..(n / 2) as VertexId {
            for _ in 0..6 {
                let d = rng.next_index(n) as VertexId;
                if d != s {
                    edges.push((s, d));
                }
            }
        }
        let g = Graph::new(n, edges);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        assert!(coo.num_edges() >= 1 << 15);
        let d = FixedPath::paper(26);
        let cfg = PprConfig { max_iterations: 3, ..Default::default() };
        let pers: Vec<VertexId> = vec![1, 2, 3, 4, 5, 6];
        let pg1 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 1));
        let base = BatchedPpr::new(d, pg1, k, 0.85).run(&pers, &cfg);
        let pg4 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 4));
        let out = BatchedPpr::new(d, pg4, k, 0.85).run(&pers, &cfg);
        assert_eq!(base.scores, out.scores);
    }

    #[test]
    fn sharded_engine_bit_identical_to_single_shard_fixed() {
        // the whole Alg. 1 loop — dangling scan, edge sweep, update — must
        // produce identical words for any shard count on the fixed path
        let g = crate::graph::generators::holme_kim(240, 4, 0.25, 13);
        let d = FixedPath::paper(24);
        let cfg = PprConfig { max_iterations: 10, ..Default::default() };
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let pg1 = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, 1));
        let base = BatchedPpr::new(d, pg1, 3, 0.85).run(&[2, 7, 11], &cfg);
        for shards in [2usize, 3, 5] {
            let pgs = Arc::new(PreparedGraph::from_coo_sharded(&coo, 8, shards));
            let mut engine = BatchedPpr::new(d, pgs, 3, 0.85);
            assert_eq!(engine.num_shards(), shards);
            let out = engine.run(&[2, 7, 11], &cfg);
            assert_eq!(out.scores, base.scores, "shards={shards}");
            assert_eq!(out.update_norms.len(), base.update_norms.len());
        }
    }
}
