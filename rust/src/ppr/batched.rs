//! Alg. 1 — κ-batched Personalized PageRank on the streaming SpMV engine,
//! generic over the arithmetic datapath. This is the bit-accurate software
//! model of the FPGA computation: every multiply, add and quantization
//! happens exactly where the hardware datapath performs it.

use super::{PprConfig, PreparedGraph};
use crate::graph::VertexId;
use crate::spmv::Datapath;
use std::sync::Arc;

/// Result of one batched PPR run.
#[derive(Debug, Clone)]
pub struct PprOutput<W> {
    /// Final scores, `num_vertices × lanes`, vertex-major
    /// (`scores[v*lanes + k]`).
    pub scores: Vec<W>,
    /// Lanes this run carried (≤ the engine's κ for partial batches).
    pub lanes: usize,
    /// Iterations actually executed.
    pub iterations: usize,
    /// Per-iteration Euclidean norm of the update, averaged over lanes
    /// (the convergence signal of Fig. 7).
    pub update_norms: Vec<f64>,
}

impl<W: Copy> PprOutput<W> {
    /// Extract lane `k` as a dense vector.
    pub fn lane(&self, k: usize, kappa: usize) -> Vec<W> {
        self.scores.iter().skip(k).step_by(kappa).copied().collect()
    }
}

/// Batched PPR engine bound to a prepared graph and a datapath.
pub struct BatchedPpr<D: Datapath> {
    /// Arithmetic datapath.
    pub datapath: D,
    /// Maximum lanes per pass (a run may carry fewer).
    pub kappa: usize,
    graph: Arc<PreparedGraph>,
    vals: Vec<D::Word>,
    // quantized constants of Eq. 1
    alpha: D::Word,
    one_minus_alpha: D::Word,
    alpha_over_v: D::Word,
}

impl<D: Datapath> BatchedPpr<D> {
    /// Bind an engine to a prepared graph. `alpha` is quantized once here,
    /// like the synthesized constants of the bitstream.
    pub fn new(datapath: D, graph: Arc<PreparedGraph>, kappa: usize, alpha: f64) -> Self {
        assert!((0.0..1.0).contains(&alpha));
        let vals = Self::quantize_vals(&datapath, &graph.sched.val);
        let alpha_w = datapath.quantize(alpha);
        let one_minus_alpha = datapath.quantize(1.0 - alpha);
        let alpha_over_v = datapath.quantize(alpha / graph.num_vertices as f64);
        Self { datapath, kappa, graph, vals, alpha: alpha_w, one_minus_alpha, alpha_over_v }
    }

    fn quantize_vals(d: &D, vals: &[f64]) -> Vec<D::Word> {
        vals.iter().map(|&v| d.quantize(v)).collect()
    }

    /// Run Alg. 1 for a batch of 1..=κ personalization vertices. Partial
    /// batches are first-class: compute scales with the lanes actually
    /// carried, and each lane is bit-identical to the same lane of any
    /// other batch shape (lanes never interact).
    pub fn run(&mut self, personalization: &[VertexId], cfg: &PprConfig) -> PprOutput<D::Word> {
        let k = personalization.len();
        assert!(
            k >= 1 && k <= self.kappa,
            "batch of {k} lanes outside 1..=κ ({})",
            self.kappa
        );
        let d = self.datapath.clone();
        let n = self.graph.num_vertices;
        let z = d.zero();
        let one = d.quantize(1.0);

        // P₁ ← V̄ : score 1 on each lane's personalization vertex
        let mut p1 = vec![z; n * k];
        for (lane, &v) in personalization.iter().enumerate() {
            p1[v as usize * k + lane] = one;
        }
        let mut p2 = vec![z; n * k];
        let mut scaling = vec![z; k];
        let mut update_norms = Vec::with_capacity(cfg.max_iterations);
        let mut iterations = 0usize;

        for _ in 0..cfg.max_iterations {
            // scaling_vec ← (α/|V|) · (d̄ · P₁)  — per lane (Alg. 1 line 6)
            for lane in 0..k {
                let mut acc = z;
                for &dv in &self.graph.dangling_idx {
                    acc = d.add(acc, p1[dv as usize * k + lane]);
                }
                scaling[lane] = d.mul(self.alpha_over_v, acc);
            }

            // P₂ ← X · P₁ (Alg. 2) — the fast kernel, bit-identical to the
            // streaming architecture model (see spmv::fast)
            crate::spmv::fast_spmv(&d, &self.graph.sched, &self.vals, k, &p1, &mut p2);

            // P₁ ← α·P₂ + scaling + (1−α)·V̄, tracking the update norm
            let mut norm_sq = 0.0f64;
            for v in 0..n {
                let row = v * k;
                for lane in 0..k {
                    let mut x = d.mul(self.alpha, p2[row + lane]);
                    x = d.add(x, scaling[lane]);
                    if personalization[lane] as usize == v {
                        x = d.add(x, self.one_minus_alpha);
                    }
                    let delta = d.abs_diff_f64(x, p1[row + lane]);
                    norm_sq += delta * delta;
                    p1[row + lane] = x;
                }
            }
            iterations += 1;
            let norm = (norm_sq / k as f64).sqrt();
            update_norms.push(norm);
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    break;
                }
            }
        }

        PprOutput { scores: p1, lanes: k, iterations, update_norms }
    }

    /// Run a whole request list by splitting it into κ-batches; returns one
    /// dense score vector per request (the host-facing result shape). The
    /// trailing batch runs partial instead of padding with repeated lanes.
    pub fn run_requests(&mut self, requests: &[VertexId], cfg: &PprConfig) -> Vec<Vec<D::Word>> {
        let mut out = Vec::with_capacity(requests.len());
        for batch in requests.chunks(self.kappa) {
            let res = self.run(batch, cfg);
            for lane in 0..batch.len() {
                out.push(res.lane(lane, batch.len()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::ppr::reference;
    use crate::spmv::datapath::{FixedPath, FloatPath};

    fn ring(n: usize) -> Graph {
        Graph::new(n, (0..n as VertexId).map(|i| (i, (i + 1) % n as VertexId)).collect())
    }

    #[test]
    fn scores_sum_to_one_ring() {
        // ring has no dangling vertices; PPR mass is conserved at 1
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let out = engine.run(&[0, 5, 9, 13], &PprConfig { max_iterations: 30, ..Default::default() });
        for lane in 0..4 {
            let sum: f64 = out.lane(lane, 4).iter().map(|&w| d.fmt.to_f64(w)).sum();
            assert!((sum - 1.0).abs() < 1e-4, "lane {lane}: {sum}");
        }
    }

    #[test]
    fn float_path_matches_f64_reference() {
        let g = crate::graph::generators::erdos_renyi(200, 0.04, 31);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 2, 0.85);
        let cfg = PprConfig { max_iterations: 20, ..Default::default() };
        let out = engine.run(&[3, 7], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        for (lane, &pv) in [3u32, 7u32].iter().enumerate() {
            let truth = reference::ppr_f64(&coo, pv, 0.85, 20, None);
            let got = out.lane(lane, 2);
            for v in 0..200 {
                assert!(
                    (got[v] as f64 - truth.scores[v]).abs() < 1e-4,
                    "lane {lane} vertex {v}: {} vs {}",
                    got[v],
                    truth.scores[v]
                );
            }
        }
    }

    #[test]
    fn fixed_close_to_reference_at_26_bits() {
        let g = crate::graph::generators::holme_kim(300, 4, 0.2, 17);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(26);
        let mut engine = BatchedPpr::new(d, pg.clone(), 1, 0.85);
        let cfg = PprConfig { max_iterations: 15, ..Default::default() };
        let out = engine.run(&[10], &cfg);
        let coo = crate::graph::CooMatrix::from_graph(&g);
        let truth = reference::ppr_f64(&coo, 10, 0.85, 15, None);
        let got = out.lane(0, 1);
        for v in 0..300 {
            assert!(
                (d.fmt.to_f64(got[v]) - truth.scores[v]).abs() < 1e-3,
                "vertex {v}"
            );
        }
    }

    #[test]
    fn personalization_vertex_ranks_first() {
        let g = crate::graph::generators::watts_strogatz(128, 6, 0.2, 3);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg.clone(), 2, 0.85);
        let out = engine.run(&[42, 100], &PprConfig::paper_timed());
        for (lane, &pv) in [42usize, 100usize].iter().enumerate() {
            let lane_scores = out.lane(lane, 2);
            let best = (0..128).max_by_key(|&v| lane_scores[v]).unwrap();
            assert_eq!(best, pv, "lane {lane}");
        }
    }

    #[test]
    fn early_exit_on_threshold() {
        let g = ring(32);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let cfg = PprConfig {
            max_iterations: 100,
            convergence_threshold: Some(1e-4),
            ..Default::default()
        };
        let out = engine.run(&[0], &cfg);
        assert!(out.iterations < 100, "should converge early, ran {}", out.iterations);
        assert!(*out.update_norms.last().unwrap() < 1e-4);
    }

    #[test]
    fn run_requests_covers_all() {
        let g = ring(64);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(22);
        let mut engine = BatchedPpr::new(d, pg.clone(), 4, 0.85);
        let reqs: Vec<VertexId> = (0..10).collect();
        // a directed ring pushes an α^t mass spike forward while
        // unconverged, so run enough iterations that α^t < 1−α
        let cfg = PprConfig { max_iterations: 50, ..Default::default() };
        let outs = engine.run_requests(&reqs, &cfg);
        assert_eq!(outs.len(), 10);
        for (i, o) in outs.iter().enumerate() {
            let best = (0..64).max_by_key(|&v| o[v]).unwrap();
            assert_eq!(best, i, "request {i} should rank itself first");
        }
    }

    #[test]
    fn partial_batch_lane_bit_identical_to_full() {
        let g = crate::graph::generators::holme_kim(200, 4, 0.25, 9);
        let pg = Arc::new(PreparedGraph::new(&g, 8));
        let d = FixedPath::paper(24);
        let mut engine = BatchedPpr::new(d, pg, 4, 0.85);
        let cfg = PprConfig { max_iterations: 12, ..Default::default() };
        let full = engine.run(&[5, 9, 33, 71], &cfg);
        let partial = engine.run(&[5, 9], &cfg);
        assert_eq!(partial.lanes, 2);
        assert_eq!(full.lanes, 4);
        // lanes never interact, so a 2-lane batch reproduces the same words
        assert_eq!(partial.lane(0, 2), full.lane(0, 4));
        assert_eq!(partial.lane(1, 2), full.lane(1, 4));
    }

    #[test]
    fn dangling_mass_redistributed() {
        // star into a sink: vertex 0..3 -> 4, vertex 4 dangling
        let g = Graph::new(5, vec![(0, 4), (1, 4), (2, 4), (3, 4)]);
        let pg = Arc::new(PreparedGraph::new(&g, 4));
        let mut engine = BatchedPpr::new(FloatPath, pg.clone(), 1, 0.85);
        let out = engine.run(&[0], &PprConfig { max_iterations: 50, ..Default::default() });
        let s = out.lane(0, 1);
        // sink collects mass, but dangling redistribution keeps the total ≈ 1
        let total: f32 = s.iter().sum();
        assert!((total - 1.0).abs() < 0.02, "total {total}");
        assert!(s[4] > s[1], "sink should outrank non-personalized leaves");
    }
}
