//! The multi-threaded CPU baseline — our stand-in for the paper's Oracle
//! PGX 19.3.1 comparison point (§5: "its state-of-the-art implementation
//! of PPR is fully multi-threaded").
//!
//! Pull-based f32 PPR over a destination-major CSR matrix, parallelized
//! across nnz-balanced vertex ranges on the persistent worker pool
//! ([`crate::runtime::pool`]). Requests
//! are processed one at a time: the paper reports that manually batching
//! requests in PGX "did not provide a speedup over the fast default
//! implementation", so the honest baseline serializes requests and
//! parallelizes within each solve.

use crate::graph::{CsrMatrix, VertexId};
use crate::util::Stopwatch;

/// Result of a baseline run over a request list.
#[derive(Debug, Clone)]
pub struct BaselineOutput {
    /// One score vector per request.
    pub scores: Vec<Vec<f32>>,
    /// Wall-clock seconds for the whole request list.
    pub seconds: f64,
}

/// Multi-threaded f32 PPR for one personalization vertex.
pub fn ppr_f32_parallel(
    m: &CsrMatrix,
    personalization: VertexId,
    alpha: f32,
    iterations: usize,
    threads: usize,
) -> Vec<f32> {
    let n = m.num_vertices;
    let mut p = vec![0.0f32; n];
    p[personalization as usize] = 1.0;
    let mut next = vec![0.0f32; n];
    let dangling: Vec<u32> = (0..n as u32).filter(|&v| m.dangling[v as usize]).collect();
    let ranges = m.balanced_ranges(threads.max(1));

    for _ in 0..iterations {
        let dangling_mass: f32 = dangling.iter().map(|&v| p[v as usize]).sum();
        let scaling = alpha / n as f32 * dangling_mass;
        // parallel pull: each range owns its slice of `next`
        let mut slices: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
        let mut rest = next.as_mut_slice();
        for r in &ranges {
            let (head, tail) = rest.split_at_mut(r.end - r.start);
            slices.push(head);
            rest = tail;
        }
        let p_ref = &p;
        // one task per range on the persistent worker pool (no per-call
        // thread spawns; see runtime::pool)
        let work: Vec<_> = ranges.iter().cloned().zip(slices).collect();
        crate::runtime::pool::global().fan_out(work, false, |(r, o)| {
            for x in r.clone() {
                let (cols, vals) = m.row(x);
                let mut acc = 0.0f32;
                for (c, &v) in cols.iter().zip(vals) {
                    acc += v as f32 * p_ref[*c as usize];
                }
                let mut val = alpha * acc + scaling;
                if x == personalization as usize {
                    val += 1.0 - alpha;
                }
                o[x - r.start] = val;
            }
        });
        std::mem::swap(&mut p, &mut next);
    }
    p
}

/// Run the paper's timed workload: a list of personalization requests,
/// each solved with `iterations` iterations at damping `alpha`, one after
/// the other, with multi-threading inside each solve. Returns scores and
/// total wall-clock time (the quantity Fig. 3's speedups divide by).
pub fn run_workload(
    m: &CsrMatrix,
    requests: &[VertexId],
    alpha: f32,
    iterations: usize,
    threads: usize,
) -> BaselineOutput {
    let sw = Stopwatch::start();
    let scores = requests
        .iter()
        .map(|&v| ppr_f32_parallel(m, v, alpha, iterations, threads))
        .collect();
    BaselineOutput { scores, seconds: sw.seconds() }
}

/// Default thread count: all available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CooMatrix, Graph};
    use crate::ppr::reference;

    #[test]
    fn matches_f64_reference() {
        let g = crate::graph::generators::erdos_renyi(500, 0.02, 44);
        let coo = CooMatrix::from_graph(&g);
        let csr = CsrMatrix::from_coo(&coo);
        let truth = reference::ppr_f64(&coo, 17, 0.85, 15, None);
        for threads in [1, 4] {
            let got = ppr_f32_parallel(&csr, 17, 0.85, 15, threads);
            for v in 0..500 {
                assert!(
                    (got[v] as f64 - truth.scores[v]).abs() < 1e-4,
                    "threads={threads} v={v}"
                );
            }
        }
    }

    #[test]
    fn thread_counts_agree_bitwise_is_not_required_but_close() {
        let g = crate::graph::generators::holme_kim(800, 3, 0.2, 45);
        let csr = CsrMatrix::from_graph(&g);
        let a = ppr_f32_parallel(&csr, 5, 0.85, 10, 1);
        let b = ppr_f32_parallel(&csr, 5, 0.85, 10, 8);
        for v in 0..800 {
            assert!((a[v] - b[v]).abs() < 1e-5, "v={v}");
        }
    }

    #[test]
    fn workload_times_and_counts() {
        let g = Graph::new(64, (0..64u32).map(|i| (i, (i + 1) % 64)).collect());
        let csr = CsrMatrix::from_graph(&g);
        // 50 iterations so the directed ring's transient α^t spike decays
        let out = run_workload(&csr, &[1, 2, 3], 0.85, 50, 2);
        assert_eq!(out.scores.len(), 3);
        assert!(out.seconds > 0.0);
        // each request ranks itself first once converged
        for (i, s) in out.scores.iter().enumerate() {
            let best = (0..64).max_by(|&a, &b| s[a].partial_cmp(&s[b]).unwrap()).unwrap();
            assert_eq!(best, i + 1);
        }
    }
}
