//! Convergence analysis (Fig. 7): per-iteration Euclidean update norms and
//! iterations-to-threshold, used to reproduce the paper's "fixed-point
//! converges 2× faster than floating-point" result.

/// A convergence trace: the Euclidean norm of `p_{t+1} − p_t` after each
/// iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvergenceTrace {
    /// Label of the run (precision name, graph, ...).
    pub label: String,
    /// Per-iteration update norms.
    pub norms: Vec<f64>,
}

impl ConvergenceTrace {
    /// Wrap a norm series.
    pub fn new(label: impl Into<String>, norms: Vec<f64>) -> Self {
        Self { label: label.into(), norms }
    }

    /// First iteration (1-based) whose update norm drops below `threshold`,
    /// or `None` if it never does. The paper uses 1e-6 as "a common
    /// convergence threshold for PPR".
    pub fn iterations_to(&self, threshold: f64) -> Option<usize> {
        self.norms.iter().position(|&n| n < threshold).map(|i| i + 1)
    }

    /// Truncate the trace below `floor` (the paper truncates plotted lines
    /// below 1e-7).
    pub fn truncated(&self, floor: f64) -> ConvergenceTrace {
        let end = self.norms.iter().position(|&n| n < floor).map(|i| i + 1).unwrap_or(self.norms.len());
        ConvergenceTrace { label: self.label.clone(), norms: self.norms[..end].to_vec() }
    }

    /// Convergence-speed ratio vs. another trace at a threshold:
    /// `other.iterations_to(th) / self.iterations_to(th)` (>1 means `self`
    /// converges faster). Returns `None` when either never converges.
    pub fn speedup_vs(&self, other: &ConvergenceTrace, threshold: f64) -> Option<f64> {
        let mine = self.iterations_to(threshold)?;
        let theirs = other.iterations_to(threshold)?;
        Some(theirs as f64 / mine as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterations_to_threshold() {
        let t = ConvergenceTrace::new("t", vec![1e-1, 1e-3, 1e-5, 1e-7]);
        assert_eq!(t.iterations_to(1e-4), Some(3));
        assert_eq!(t.iterations_to(1e-9), None);
        assert_eq!(t.iterations_to(1.0), Some(1));
    }

    #[test]
    fn truncation() {
        let t = ConvergenceTrace::new("t", vec![1e-1, 1e-3, 1e-8, 1e-9]);
        let tt = t.truncated(1e-7);
        assert_eq!(tt.norms.len(), 3);
    }

    #[test]
    fn speedup_ratio() {
        let fixed = ConvergenceTrace::new("26b", vec![1e-2, 1e-4, 1e-7]);
        let float = ConvergenceTrace::new("F32", vec![1e-1, 1e-2, 1e-4, 1e-5, 1e-6, 1e-7]);
        // fixed reaches 1e-6 at iter 3, float at iter 6 → 2x
        assert_eq!(fixed.speedup_vs(&float, 1e-6), Some(2.0));
    }
}
