//! f64 ground-truth PPR solver. The paper's accuracy analysis compares
//! fixed-point rankings after 10 iterations against "the CPU implementation
//! at convergence (with at least 100 iterations)" — this module is that
//! oracle, in full double precision.

use crate::graph::{CooMatrix, VertexId};

/// Result of a reference solve.
#[derive(Debug, Clone)]
pub struct ReferenceOutput {
    /// Final scores (length |V|).
    pub scores: Vec<f64>,
    /// Iterations executed.
    pub iterations: usize,
    /// Per-iteration update norms.
    pub update_norms: Vec<f64>,
}

/// Solve PPR in f64 for one personalization vertex.
///
/// `threshold`: early exit when the update's Euclidean norm drops below it
/// (pass `None` to run exactly `max_iter` iterations).
pub fn ppr_f64(
    coo: &CooMatrix,
    personalization: VertexId,
    alpha: f64,
    max_iter: usize,
    threshold: Option<f64>,
) -> ReferenceOutput {
    let n = coo.num_vertices;
    assert!((personalization as usize) < n);
    let mut p = vec![0.0f64; n];
    p[personalization as usize] = 1.0;
    let mut next = vec![0.0f64; n];
    let mut update_norms = Vec::new();
    let mut iterations = 0;

    for _ in 0..max_iter {
        // dangling mass
        let dangling_mass: f64 = coo
            .dangling
            .iter()
            .enumerate()
            .filter(|(_, &d)| d)
            .map(|(v, _)| p[v])
            .sum();
        let scaling = alpha / n as f64 * dangling_mass;

        // α·X·p
        next.fill(0.0);
        for i in 0..coo.num_edges() {
            next[coo.x[i] as usize] += coo.val[i] * p[coo.y[i] as usize];
        }
        let mut norm_sq = 0.0;
        for v in 0..n {
            let mut x = alpha * next[v] + scaling;
            if v == personalization as usize {
                x += 1.0 - alpha;
            }
            let d = x - p[v];
            norm_sq += d * d;
            next[v] = x;
        }
        std::mem::swap(&mut p, &mut next);
        iterations += 1;
        let norm = norm_sq.sqrt();
        update_norms.push(norm);
        if let Some(th) = threshold {
            if norm < th {
                break;
            }
        }
    }
    ReferenceOutput { scores: p, iterations, update_norms }
}

/// Ground truth for a batch of personalization vertices (paper setting:
/// α=0.85, 100 iterations, tight threshold).
pub fn ground_truth_batch(coo: &CooMatrix, requests: &[VertexId]) -> Vec<Vec<f64>> {
    requests
        .iter()
        .map(|&v| ppr_f64(coo, v, crate::PAPER_ALPHA, 100, Some(1e-12)).scores)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn mass_conserved() {
        let g = crate::graph::generators::erdos_renyi(100, 0.05, 1);
        let coo = CooMatrix::from_graph(&g);
        let out = ppr_f64(&coo, 3, 0.85, 50, None);
        let total: f64 = out.scores.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn converges_monotonically_late() {
        let g = crate::graph::generators::watts_strogatz(100, 6, 0.1, 2);
        let coo = CooMatrix::from_graph(&g);
        // update norms decay like α^t ≈ 0.85^t, so 1e-4 needs ~57 iters
        let out = ppr_f64(&coo, 0, 0.85, 100, Some(1e-4));
        assert!(out.iterations < 100);
        // norms eventually decay below the first norm
        assert!(out.update_norms.last().unwrap() < &out.update_norms[0]);
    }

    #[test]
    fn two_vertex_analytic() {
        // 0 <-> 1: X = [[0,1],[1,0]]; PPR from 0 solves
        // p0 = α p1 + (1-α), p1 = α p0  →  p0 = (1-α)/(1-α²), p1 = α p0
        let g = Graph::new(2, vec![(0, 1), (1, 0)]);
        let coo = CooMatrix::from_graph(&g);
        let a: f64 = 0.85;
        let out = ppr_f64(&coo, 0, a, 200, Some(1e-14));
        let p0 = (1.0 - a) / (1.0 - a * a);
        assert!((out.scores[0] - p0).abs() < 1e-10);
        assert!((out.scores[1] - a * p0).abs() < 1e-10);
    }

    #[test]
    fn teleport_only_when_alpha_zero() {
        let g = Graph::new(3, vec![(0, 1), (1, 2), (2, 0)]);
        let coo = CooMatrix::from_graph(&g);
        let out = ppr_f64(&coo, 1, 0.0, 5, None);
        assert_eq!(out.scores, vec![0.0, 1.0, 0.0]);
    }
}
