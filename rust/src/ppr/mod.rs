//! Personalized PageRank solvers (§3–§4.1 of the paper).
//!
//! The recurrence (Eq. 1):
//!
//! ```text
//! p_{t+1} = α·X·p_t + (α/|V|)·(d̄·p_t)·1 + (1−α)·v̄
//! ```
//!
//! - [`batched`] — the paper's Alg. 1: κ personalization vertices advanced
//!   per pass over the edges, running on the streaming SpMV engine with a
//!   generic datapath (the "FPGA algorithm", bit-accurate per width).
//! - [`cpu_baseline`] — the PGX analogue: multi-threaded f32 pull-based
//!   PPR, one request at a time (the paper found PGX gained nothing from
//!   manual batching).
//! - [`reference`] — f64 solver run to convergence: the ground truth the
//!   accuracy metrics compare against ("CPU implementation at
//!   convergence, with at least 100 iterations").
//! - [`convergence`] — per-iteration Euclidean-norm tracking (Fig. 7).

pub mod batched;
pub mod convergence;
pub mod cpu_baseline;
pub mod reference;

pub use batched::{BatchedPpr, PprOutput};
pub use convergence::ConvergenceTrace;

use crate::graph::{CooMatrix, Graph, VertexId};
use crate::spmv::PacketSchedule;

/// Solver parameters shared by every engine.
#[derive(Debug, Clone, Copy)]
pub struct PprConfig {
    /// Damping factor α (paper: 0.85).
    pub alpha: f64,
    /// Maximum iterations (paper: 10 for timed runs, ≥100 for ground truth).
    pub max_iterations: usize,
    /// Optional early-exit threshold on the Euclidean norm of the update
    /// (paper §5.3.2 uses 1e-6 as the common convergence threshold).
    pub convergence_threshold: Option<f64>,
}

impl Default for PprConfig {
    fn default() -> Self {
        Self {
            alpha: crate::PAPER_ALPHA,
            max_iterations: crate::PAPER_ITERATIONS,
            convergence_threshold: None,
        }
    }
}

impl PprConfig {
    /// The paper's timed-experiment configuration (α=0.85, 10 iterations,
    /// no early exit).
    pub fn paper_timed() -> Self {
        Self::default()
    }

    /// Ground-truth configuration: run to numerical convergence with a
    /// generous iteration budget.
    pub fn ground_truth() -> Self {
        Self { alpha: crate::PAPER_ALPHA, max_iterations: 100, convergence_threshold: Some(1e-12) }
    }
}

/// Graph-derived state shared by solver instances: the aligned packet
/// schedule (FPGA DRAM layout) plus the dangling-vertex index list used by
/// the scaling-vector computation (Alg. 1 line 6).
#[derive(Debug, Clone)]
pub struct PreparedGraph {
    /// The aligned COO packet schedule.
    pub sched: PacketSchedule,
    /// Indices of dangling vertices (outdeg = 0).
    pub dangling_idx: Vec<VertexId>,
    /// |V|.
    pub num_vertices: usize,
}

impl PreparedGraph {
    /// Preprocess a graph for packet width `b` (host-side, once per graph;
    /// the paper reports this takes <1% of execution time, §4.2).
    pub fn new(g: &Graph, b: usize) -> Self {
        let coo = CooMatrix::from_graph(g);
        Self::from_coo(&coo, b)
    }

    /// Preprocess an existing COO matrix.
    pub fn from_coo(coo: &CooMatrix, b: usize) -> Self {
        let sched = PacketSchedule::build(coo, b);
        let dangling_idx = (0..coo.num_vertices as VertexId)
            .filter(|&v| coo.dangling[v as usize])
            .collect();
        Self { sched, dangling_idx, num_vertices: coo.num_vertices }
    }
}

/// Split a request list into κ-sized batches (the last batch may repeat
/// the final vertex to fill all lanes, mirroring how the hardware always
/// runs κ lanes).
pub fn batch_requests(requests: &[VertexId], kappa: usize) -> Vec<Vec<VertexId>> {
    assert!(kappa >= 1);
    assert!(!requests.is_empty());
    requests
        .chunks(kappa)
        .map(|c| {
            let mut batch = c.to_vec();
            while batch.len() < kappa {
                batch.push(*c.last().unwrap());
            }
            batch
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_pads_last() {
        let b = batch_requests(&[1, 2, 3, 4, 5], 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], vec![1, 2, 3, 4]);
        assert_eq!(b[1], vec![5, 5, 5, 5]);
    }

    #[test]
    fn prepared_graph_collects_dangling() {
        let g = Graph::new(4, vec![(0, 1), (1, 2)]);
        let pg = PreparedGraph::new(&g, 4);
        assert_eq!(pg.dangling_idx, vec![2, 3]);
        assert_eq!(pg.num_vertices, 4);
    }

    #[test]
    fn config_presets() {
        let t = PprConfig::paper_timed();
        assert_eq!(t.max_iterations, 10);
        assert!(t.convergence_threshold.is_none());
        let g = PprConfig::ground_truth();
        assert_eq!(g.max_iterations, 100);
    }
}
