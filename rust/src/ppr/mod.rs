//! Personalized PageRank solvers (§3–§4.1 of the paper).
//!
//! The recurrence (Eq. 1):
//!
//! ```text
//! p_{t+1} = α·X·p_t + (α/|V|)·(d̄·p_t)·1 + (1−α)·v̄
//! ```
//!
//! - [`batched`] — the paper's Alg. 1: κ personalization vertices advanced
//!   per pass over the edges, running on the streaming SpMV engine with a
//!   generic datapath (the "FPGA algorithm", bit-accurate per width).
//! - [`ladder`] — the adaptive precision ladder: runs start on a narrow
//!   rung (Q1.15) and hot-switch to wider rungs as the update norm stalls
//!   at each rung's quantization floor (DESIGN.md §7).
//! - [`cpu_baseline`] — the PGX analogue: multi-threaded f32 pull-based
//!   PPR, one request at a time (the paper found PGX gained nothing from
//!   manual batching).
//! - [`reference`] — f64 solver run to convergence: the ground truth the
//!   accuracy metrics compare against ("CPU implementation at
//!   convergence, with at least 100 iterations").
//! - [`convergence`] — per-iteration Euclidean-norm tracking (Fig. 7).

pub mod batched;
pub mod convergence;
pub mod cpu_baseline;
pub mod ladder;
pub mod reference;

pub use batched::{copy_lane, BatchedPpr, Executor, PprOutput, PprRun, SegmentStop};
pub use convergence::ConvergenceTrace;
pub use ladder::{LadderOutput, LadderPpr, LadderScores, RungSegment, ValueStreams};

use crate::graph::{CooMatrix, Graph, VertexId};
use crate::spmv::{PacketSchedule, ShardedSchedule};
use std::sync::OnceLock;

/// Solver parameters shared by every engine.
#[derive(Debug, Clone, Copy)]
pub struct PprConfig {
    /// Damping factor α (paper: 0.85).
    pub alpha: f64,
    /// Maximum iterations (paper: 10 for timed runs, ≥100 for ground truth).
    pub max_iterations: usize,
    /// Optional early-exit threshold on the Euclidean norm of the update
    /// (paper §5.3.2 uses 1e-6 as the common convergence threshold).
    pub convergence_threshold: Option<f64>,
    /// Top-K-native mode (`Some(K)`, K ≥ 1): the fused sweep carries
    /// per-shard streaming candidate heaps and the run also returns the
    /// per-lane top-K ranking plus the write-back pruning ledger
    /// ([`batched::PprOutput::topk`]). Scores, norms and iteration counts
    /// are bit-identical to `None` — the heaps only observe the stream.
    pub top_k: Option<usize>,
}

impl Default for PprConfig {
    fn default() -> Self {
        Self {
            alpha: crate::PAPER_ALPHA,
            max_iterations: crate::PAPER_ITERATIONS,
            convergence_threshold: None,
            top_k: None,
        }
    }
}

impl PprConfig {
    /// The paper's timed-experiment configuration (α=0.85, 10 iterations,
    /// no early exit).
    pub fn paper_timed() -> Self {
        Self::default()
    }

    /// Ground-truth configuration: run to numerical convergence with a
    /// generous iteration budget.
    pub fn ground_truth() -> Self {
        Self {
            alpha: crate::PAPER_ALPHA,
            max_iterations: 100,
            convergence_threshold: Some(1e-12),
            top_k: None,
        }
    }
}

/// Graph-derived state shared by solver instances: the single-channel
/// aligned packet schedule (the architecture reference layout, also what
/// the PJRT artifacts are marshalled from), the destination-partitioned
/// sharded schedule (the multi-CU serving layout the native engine runs),
/// and the dangling-vertex index list used by the scaling-vector
/// computation (Alg. 1 line 6).
#[derive(Debug, Clone)]
pub struct PreparedGraph {
    /// The aligned COO packet schedule (one stream, one DRAM channel).
    /// RAM preparation fills this eagerly; artifact-loaded graphs derive
    /// it lazily from the shard streams on first use — see
    /// [`Self::sched`].
    sched: OnceLock<PacketSchedule>,
    /// The destination-partitioned packet schedule (one stream per shard;
    /// with one shard its stream is identical to `sched`'s).
    pub sharded: ShardedSchedule,
    /// Indices of dangling vertices (outdeg = 0), all shards combined.
    pub dangling_idx: Vec<VertexId>,
    /// |V|.
    pub num_vertices: usize,
}

impl PreparedGraph {
    /// Preprocess a graph for packet width `b` with a single shard
    /// (host-side, once per graph; the paper reports this takes <1% of
    /// execution time, §4.2).
    pub fn new(g: &Graph, b: usize) -> Self {
        Self::new_sharded(g, b, 1)
    }

    /// Preprocess a graph for packet width `b` and `num_shards` compute
    /// units (destination-partitioned, nnz-balanced).
    pub fn new_sharded(g: &Graph, b: usize, num_shards: usize) -> Self {
        let coo = CooMatrix::from_graph(g);
        Self::from_coo_sharded(&coo, b, num_shards)
    }

    /// Preprocess an existing COO matrix with a single shard.
    pub fn from_coo(coo: &CooMatrix, b: usize) -> Self {
        Self::from_coo_sharded(coo, b, 1)
    }

    /// Preprocess an existing COO matrix into `num_shards` sub-streams.
    ///
    /// Both layouts are retained: the native engine sweeps `sharded`, the
    /// PJRT marshaller and the architecture model read [`Self::sched`].
    pub fn from_coo_sharded(coo: &CooMatrix, b: usize, num_shards: usize) -> Self {
        let sched = PacketSchedule::build(coo, b);
        let sharded = if num_shards == 1 {
            // the one-shard stream is the single stream: skip re-aligning
            ShardedSchedule::from_packet_schedule(&sched)
        } else {
            ShardedSchedule::build(coo, b, num_shards)
        };
        let dangling_idx = (0..coo.num_vertices as VertexId)
            .filter(|&v| coo.dangling[v as usize])
            .collect();
        let cell = OnceLock::new();
        cell.set(sched).expect("fresh cell");
        Self { sched: cell, sharded, dangling_idx, num_vertices: coo.num_vertices }
    }

    /// Wrap an already-built sharded schedule (e.g. one loaded zero-copy
    /// from a schedule artifact, [`crate::spmv::artifact`]); the
    /// single-stream layout is derived lazily on first use so the mmap'd
    /// hot path pays nothing for it.
    pub fn from_sharded(sharded: ShardedSchedule) -> Self {
        let num_vertices = sharded.num_vertices;
        let dangling_idx = sharded
            .shards
            .iter()
            .flat_map(|s| s.dangling_idx.iter().copied())
            .collect();
        Self { sched: OnceLock::new(), sharded, dangling_idx, num_vertices }
    }

    /// The single-stream packet schedule. RAM-prepared graphs return the
    /// eagerly built stream; artifact-loaded graphs reconstruct it once,
    /// on first use, by de-padding the shard streams (padding slots are
    /// exactly the `val == 0.0` slots — real transition-matrix values are
    /// `1/outdeg > 0`), concatenating them back into the destination-
    /// sorted edge stream, and re-aligning. The reconstruction is
    /// bit-identical to building from the COO matrix directly because
    /// shard ranges tile the destination axis in order and alignment
    /// preserves the relative order of real edges.
    pub fn sched(&self) -> &PacketSchedule {
        self.sched.get_or_init(|| derive_single_stream(&self.sharded))
    }

    /// Number of shards (compute units) the graph was prepared for.
    pub fn num_shards(&self) -> usize {
        self.sharded.num_shards()
    }
}

/// Rebuild the single-channel packet schedule from the shard streams.
/// See [`PreparedGraph::sched`] for the padding-recovery argument.
fn derive_single_stream(sharded: &ShardedSchedule) -> PacketSchedule {
    let mut x = Vec::with_capacity(sharded.num_edges);
    let mut y = Vec::with_capacity(sharded.num_edges);
    let mut val = Vec::with_capacity(sharded.num_edges);
    for s in &sharded.shards {
        for i in 0..s.num_slots() {
            let v = s.val[i];
            if v != 0.0 {
                x.push(s.x[i]);
                y.push(s.y[i]);
                val.push(v);
            }
        }
    }
    assert_eq!(
        x.len(),
        sharded.num_edges,
        "de-padded shard streams must recover exactly the real edges"
    );
    let (x, y, val) = crate::spmv::packets::align_stream(sharded.b, &x, &y, &val);
    let mut dangling = vec![false; sharded.num_vertices];
    for s in &sharded.shards {
        for &v in &s.dangling_idx {
            dangling[v as usize] = true;
        }
    }
    PacketSchedule {
        b: sharded.b,
        num_vertices: sharded.num_vertices,
        num_edges: sharded.num_edges,
        x,
        y,
        val,
        dangling,
    }
}

/// Split a request list into κ-sized batches (the last batch may repeat
/// the final vertex to fill all lanes, mirroring how the hardware always
/// runs κ lanes).
pub fn batch_requests(requests: &[VertexId], kappa: usize) -> Vec<Vec<VertexId>> {
    assert!(kappa >= 1);
    assert!(!requests.is_empty());
    requests
        .chunks(kappa)
        .map(|c| {
            let mut batch = c.to_vec();
            while batch.len() < kappa {
                batch.push(*c.last().unwrap());
            }
            batch
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_pads_last() {
        let b = batch_requests(&[1, 2, 3, 4, 5], 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0], vec![1, 2, 3, 4]);
        assert_eq!(b[1], vec![5, 5, 5, 5]);
    }

    #[test]
    fn prepared_graph_collects_dangling() {
        let g = Graph::new(4, vec![(0, 1), (1, 2)]);
        let pg = PreparedGraph::new(&g, 4);
        assert_eq!(pg.dangling_idx, vec![2, 3]);
        assert_eq!(pg.num_vertices, 4);
        assert_eq!(pg.num_shards(), 1);
    }

    #[test]
    fn prepared_graph_sharded_partitions_dangling() {
        let g = Graph::new(6, vec![(0, 1), (1, 2), (2, 3)]);
        let pg = PreparedGraph::new_sharded(&g, 4, 3);
        assert_eq!(pg.num_shards(), 3);
        pg.sharded.validate().unwrap();
        let merged: Vec<VertexId> =
            pg.sharded.shards.iter().flat_map(|s| s.dangling_idx.iter().copied()).collect();
        assert_eq!(merged, pg.dangling_idx);
    }

    #[test]
    fn lazy_single_stream_matches_eager_bit_exact() {
        // an artifact-loaded graph derives `sched` from its shard streams;
        // the reconstruction must equal the eager COO-built stream exactly
        let g = crate::graph::generators::holme_kim(200, 4, 0.3, 5);
        for shards in [1usize, 3, 4] {
            let eager = PreparedGraph::new_sharded(&g, 8, shards);
            let lazy = PreparedGraph::from_sharded(eager.sharded.clone());
            assert_eq!(lazy.dangling_idx, eager.dangling_idx, "shards={shards}");
            let a = eager.sched();
            let b = lazy.sched();
            assert_eq!(a.x, b.x, "shards={shards}");
            assert_eq!(a.y, b.y);
            assert_eq!(a.val, b.val);
            assert_eq!(a.dangling, b.dangling);
            assert_eq!(a.num_edges, b.num_edges);
            b.validate().expect("reconstructed stream validates");
        }
    }

    #[test]
    fn config_presets() {
        let t = PprConfig::paper_timed();
        assert_eq!(t.max_iterations, 10);
        assert!(t.convergence_threshold.is_none());
        let g = PprConfig::ground_truth();
        assert_eq!(g.max_iterations, 100);
    }
}
