//! Plain-text table rendering for the benchmark harness, so every
//! experiment prints rows directly comparable to the paper's tables and
//! figures. Also provides a minimal CSV writer for post-processing.

use std::fmt::Write as _;

/// A simple left-aligned text table with a title, printed in the style used
/// throughout `EXPERIMENTS.md`.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: append a row of `&str`.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for i in 0..ncols {
                let _ = write!(s, "{:<w$}", cells[i], w = widths[i]);
                if i + 1 < ncols {
                    s.push_str("  ");
                }
            }
            s
        };
        let header_line = line(&self.header, &widths);
        let _ = writeln!(out, "{header_line}");
        let _ = writeln!(out, "{}", "-".repeat(header_line.len()));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and, if `csv_path` is set, also write CSV there.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        print!("{}", self.render());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(p, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", p.display());
            } else {
                println!("[csv written to {}]", p.display());
            }
        }
        println!();
    }
}

/// Format a float with `digits` significant decimals, trimming noise.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a ratio as `N.NNx`.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a fraction as a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row_str(&["xx", "y"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("a   bbbb"));
        assert!(r.contains("xx  y"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("demo", &["a"]);
        t.row_str(&["x,y"]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_speedup(6.47), "6.47x");
        assert_eq!(fmt_pct(0.955), "95.5%");
        assert_eq!(fmt_f(1.23456, 2), "1.23");
    }
}
