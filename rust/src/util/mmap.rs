//! Read-only file mappings and [`PodVec`] — the zero-copy slice container
//! behind out-of-core schedule artifacts (DESIGN.md §11).
//!
//! The build vendors no external crates (DESIGN.md §1), so [`Mmap`] is a
//! thin FFI shim over the platform's `mmap`/`munmap`/`madvise` (plus a
//! `posix_fadvise(SEQUENTIAL)` hint on Linux) rather than a `memmap2`
//! dependency. On non-unix targets — or when the mapping call fails — it
//! degrades to reading the whole file into an owned, 8-byte-aligned
//! buffer, so callers never observe the difference beyond RSS.
//!
//! [`PodVec<T>`] is the unification layer: every packet-stream field of a
//! [`ShardStream`](crate::spmv::ShardStream) is either an owned `Vec<T>`
//! (RAM-prepared) or a typed window into a shared [`Mmap`]
//! (artifact-loaded). It derefs to `&[T]`, so the sweep kernels consume
//! both representations through one code path with no copies on the hot
//! path.

use anyhow::{ensure, Context, Result};
use std::fmt;
use std::fs::File;
use std::path::Path;
use std::sync::Arc;

/// A read-only view of a file's bytes: a real memory mapping where the
/// platform provides one, an owned 8-byte-aligned buffer otherwise. The
/// base address is always at least 8-byte aligned (page-aligned for real
/// mappings), so sections laid out on 8-byte boundaries can be viewed as
/// typed slices of `u32`/`u64`/`f32`/`f64`.
pub struct Mmap {
    repr: MapRepr,
}

enum MapRepr {
    #[cfg(unix)]
    Mapped {
        ptr: *mut u8,
        len: usize,
    },
    /// Fallback storage: `u64` elements guarantee 8-byte alignment; `len`
    /// is the file's byte length (the tail of the last word is padding).
    Owned {
        buf: Vec<u64>,
        len: usize,
    },
}

// Safety: the mapping is created PROT_READ and never written through; the
// owned fallback is plain memory. Either way the bytes are immutable for
// the lifetime of the value, so shared references from any thread are fine.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only, falling back to an owned in-memory copy when
    /// the platform call is unavailable or fails.
    pub fn open(path: &Path) -> Result<Mmap> {
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let meta = file.metadata().with_context(|| format!("stat {}", path.display()))?;
        let len = usize::try_from(meta.len()).context("file too large to map")?;
        #[cfg(unix)]
        if len > 0 {
            if let Some(ptr) = unsafe { sys::map_readonly(&file, len) } {
                return Ok(Mmap { repr: MapRepr::Mapped { ptr, len } });
            }
        }
        Self::read_owned(file, len, path)
    }

    /// Fallback: read the whole file into an 8-byte-aligned owned buffer.
    fn read_owned(mut file: File, len: usize, path: &Path) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = vec![0u64; len.div_ceil(8)];
        if len > 0 {
            // Safety: u64 storage reinterpreted as bytes for the read;
            // every bit pattern is a valid u64.
            let bytes =
                unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, len) };
            file.read_exact(bytes).with_context(|| format!("read {}", path.display()))?;
        }
        Ok(Mmap { repr: MapRepr::Owned { buf, len } })
    }

    /// The file's bytes.
    pub fn as_bytes(&self) -> &[u8] {
        match &self.repr {
            #[cfg(unix)]
            MapRepr::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            MapRepr::Owned { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len)
            },
        }
    }

    /// Byte length of the view.
    pub fn len(&self) -> usize {
        match &self.repr {
            #[cfg(unix)]
            MapRepr::Mapped { len, .. } => *len,
            MapRepr::Owned { len, .. } => *len,
        }
    }

    /// Whether the file is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when backed by a real memory mapping (diagnostics: the owned
    /// fallback is correct but pays full-file RSS up front).
    pub fn is_mapped(&self) -> bool {
        match &self.repr {
            #[cfg(unix)]
            MapRepr::Mapped { .. } => true,
            MapRepr::Owned { .. } => false,
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let MapRepr::Mapped { ptr, len } = &self.repr {
            unsafe { sys::unmap(*ptr, *len) };
        }
    }
}

impl fmt::Debug for Mmap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mmap")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(unix)]
mod sys {
    //! Direct FFI onto the C library's mapping calls. std links libc on
    //! every unix target, so these symbols resolve without a `libc` crate.
    use std::ffi::{c_int, c_void};
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
        fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
        #[cfg(target_os = "linux")]
        fn posix_fadvise(fd: c_int, offset: i64, len: i64, advice: c_int) -> c_int;
    }

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;
    /// Same value on Linux and the BSDs (macOS included).
    const MADV_SEQUENTIAL: c_int = 2;
    #[cfg(target_os = "linux")]
    const POSIX_FADV_SEQUENTIAL: c_int = 2;

    /// Map `len` bytes of `file` read-only; `None` when the platform call
    /// fails (caller falls back to an owned read). Advice failures are
    /// ignored — hints only.
    pub(super) unsafe fn map_readonly(file: &File, len: usize) -> Option<*mut u8> {
        let fd = file.as_raw_fd();
        #[cfg(target_os = "linux")]
        {
            // tell the page cache the upcoming scan is sequential
            posix_fadvise(fd, 0, len as i64, POSIX_FADV_SEQUENTIAL);
        }
        let ptr = mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, fd, 0);
        if ptr.is_null() || ptr as isize == -1 {
            return None;
        }
        // packet streams are consumed front-to-back: prime readahead
        madvise(ptr, len, MADV_SEQUENTIAL);
        Some(ptr as *mut u8)
    }

    pub(super) unsafe fn unmap(ptr: *mut u8, len: usize) {
        munmap(ptr as *mut c_void, len);
    }
}

/// Marker for plain-old-data element types a [`PodVec`] may hold.
///
/// # Safety
///
/// Implementors must be `Copy` types for which **every** bit pattern is a
/// valid value and which contain no padding or pointers — raw file bytes
/// are reinterpreted as `&[T]`.
pub unsafe trait Pod: Copy + Send + Sync + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// A read-only slice of POD elements: either an owned `Vec<T>` or a typed
/// zero-copy window into a shared [`Mmap`]. Derefs to `&[T]`, so the sweep
/// kernels are agnostic to where the packet stream lives.
pub struct PodVec<T: Pod> {
    repr: VecRepr<T>,
}

enum VecRepr<T: Pod> {
    Owned(Vec<T>),
    Mapped { map: Arc<Mmap>, offset: usize, len: usize },
}

impl<T: Pod> PodVec<T> {
    /// An owned, empty vector.
    pub fn new() -> Self {
        PodVec { repr: VecRepr::Owned(Vec::new()) }
    }

    /// A zero-copy view of `len` elements starting `offset` bytes into
    /// `map`. Rejects out-of-range and misaligned windows — the artifact
    /// writer lays every section on an 8-byte boundary precisely so this
    /// check always passes for well-formed files.
    pub fn from_mapped(map: Arc<Mmap>, offset: usize, len: usize) -> Result<PodVec<T>> {
        let size = std::mem::size_of::<T>();
        let bytes = len.checked_mul(size).context("section length overflows")?;
        let end = offset.checked_add(bytes).context("section range overflows")?;
        ensure!(
            end <= map.len(),
            "section [{offset}, {end}) exceeds file length {}",
            map.len()
        );
        let align = std::mem::align_of::<T>();
        ensure!(offset % align == 0, "section offset {offset} misaligned for {size}-byte items");
        ensure!(
            (map.as_bytes().as_ptr() as usize) % align == 0,
            "mapping base misaligned for {size}-byte items"
        );
        Ok(PodVec { repr: VecRepr::Mapped { map, offset, len } })
    }

    /// The elements as a slice.
    pub fn as_slice(&self) -> &[T] {
        match &self.repr {
            VecRepr::Owned(v) => v.as_slice(),
            VecRepr::Mapped { map, offset, len } => unsafe {
                // Safety: bounds and alignment were validated by
                // `from_mapped`, the mapping is immutable and outlives
                // `self` via the `Arc`, and `T: Pod` admits any bytes.
                let base = map.as_bytes().as_ptr().add(*offset) as *const T;
                std::slice::from_raw_parts(base, *len)
            },
        }
    }

    /// Materialize an owned copy of the elements.
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    /// True when this is a zero-copy window into a mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(&self.repr, VecRepr::Mapped { .. })
    }
}

impl<T: Pod> Default for PodVec<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Pod> From<Vec<T>> for PodVec<T> {
    fn from(v: Vec<T>) -> Self {
        PodVec { repr: VecRepr::Owned(v) }
    }
}

impl<T: Pod> std::ops::Deref for PodVec<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> AsRef<[T]> for PodVec<T> {
    fn as_ref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> Clone for PodVec<T> {
    fn clone(&self) -> Self {
        match &self.repr {
            VecRepr::Owned(v) => PodVec { repr: VecRepr::Owned(v.clone()) },
            VecRepr::Mapped { map, offset, len } => PodVec {
                repr: VecRepr::Mapped { map: map.clone(), offset: *offset, len: *len },
            },
        }
    }
}

impl<T: Pod + fmt::Debug> fmt::Debug for PodVec<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_list().entries(self.as_slice()).finish()
    }
}

impl<T: Pod + PartialEq> PartialEq for PodVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq> PartialEq<Vec<T>> for PodVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + PartialEq> PartialEq<PodVec<T>> for Vec<T> {
    fn eq(&self, other: &PodVec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<'a, T: Pod> IntoIterator for &'a PodVec<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ppr-mmap-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}-{}", std::process::id()));
        let mut f = File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        path
    }

    #[test]
    fn mmap_round_trips_bytes() {
        let data: Vec<u8> = (0..=255u8).collect();
        let path = tmp_file("roundtrip", &data);
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.len(), 256);
        assert_eq!(m.as_bytes(), &data[..]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mmap_empty_file_is_empty() {
        let path = tmp_file("empty", &[]);
        let m = Mmap::open(&path).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_bytes(), &[] as &[u8]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mmap_missing_file_errors() {
        assert!(Mmap::open(Path::new("/nonexistent/ppr-no-such-file")).is_err());
    }

    #[test]
    fn owned_fallback_is_aligned_and_identical() {
        let data: Vec<u8> = (0..100u8).collect();
        let path = tmp_file("fallback", &data);
        let file = File::open(&path).unwrap();
        let m = Mmap::read_owned(file, data.len(), &path).unwrap();
        assert!(!m.is_mapped());
        assert_eq!(m.as_bytes(), &data[..]);
        assert_eq!(m.as_bytes().as_ptr() as usize % 8, 0, "owned base is 8-aligned");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn podvec_owned_and_mapped_views_agree() {
        let vals: Vec<u64> = (0..32u64).map(|i| i.wrapping_mul(0x9E3779B97F4A7C15)).collect();
        let mut bytes = Vec::new();
        for v in &vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let path = tmp_file("podvec", &bytes);
        let map = Arc::new(Mmap::open(&path).unwrap());

        let owned: PodVec<u64> = vals.clone().into();
        let mapped: PodVec<u64> = PodVec::from_mapped(map.clone(), 0, vals.len()).unwrap();
        assert!(!owned.is_mapped());
        assert!(mapped.is_mapped() == map.is_mapped());
        assert_eq!(owned, mapped);
        assert_eq!(mapped, vals);
        assert_eq!(vals, mapped);
        assert_eq!(mapped.to_vec(), vals);
        assert_eq!(mapped.iter().copied().sum::<u64>(), vals.iter().copied().sum::<u64>());

        // a window into the middle, and clones sharing the same mapping
        let tail: PodVec<u64> = PodVec::from_mapped(map.clone(), 8 * 8, vals.len() - 8).unwrap();
        assert_eq!(tail.as_slice(), &vals[8..]);
        let c = tail.clone();
        assert_eq!(c, tail);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn podvec_rejects_bad_windows() {
        let path = tmp_file("badwin", &[0u8; 64]);
        let map = Arc::new(Mmap::open(&path).unwrap());
        // out of range
        assert!(PodVec::<u64>::from_mapped(map.clone(), 0, 9).is_err());
        // misaligned offset
        assert!(PodVec::<u64>::from_mapped(map.clone(), 4, 1).is_err());
        // in-range u32 window is fine
        assert!(PodVec::<u32>::from_mapped(map.clone(), 4, 15).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
