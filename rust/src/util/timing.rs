//! Wall-clock measurement helpers used by the CPU baseline and the
//! benchmark harness (criterion is unavailable offline, so benches use
//! these directly with warmup + repeated samples).

use std::time::{Duration, Instant};

/// Simple stopwatch over `Instant`.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as `f64`.
    pub fn seconds(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as `f64`.
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }

    /// Restart and return the previous elapsed duration.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over repeated timing samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Samples {
    /// Number of samples taken.
    pub n: usize,
    /// Minimum sample (seconds).
    pub min: f64,
    /// Median sample (seconds).
    pub median: f64,
    /// Mean sample (seconds).
    pub mean: f64,
    /// Maximum sample (seconds).
    pub max: f64,
    /// Sample standard deviation (seconds).
    pub stddev: f64,
}

impl Samples {
    /// Compute summary statistics from raw samples (seconds).
    pub fn from_raw(mut xs: Vec<f64>) -> Self {
        assert!(!xs.is_empty(), "no samples");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let median = if n % 2 == 1 {
            xs[n / 2]
        } else {
            0.5 * (xs[n / 2 - 1] + xs[n / 2])
        };
        Self { n, min: xs[0], median, mean, max: xs[n - 1], stddev: var.sqrt() }
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `samples` measured
/// runs; returns summary stats in seconds. The closure's return value is
/// passed through `std::hint::black_box` to stop the optimizer from
/// removing the work.
pub fn bench<T, F: FnMut() -> T>(warmup: usize, samples: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let raw: Vec<f64> = (0..samples)
        .map(|_| {
            let sw = Stopwatch::start();
            std::hint::black_box(f());
            sw.seconds()
        })
        .collect();
    Samples::from_raw(raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stats() {
        let s = Samples::from_raw(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.stddev - 1.0).abs() < 1e-12);
    }

    #[test]
    fn samples_even_median() {
        let s = Samples::from_raw(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_and_counts() {
        let mut calls = 0usize;
        let s = bench(2, 5, || {
            calls += 1;
            calls
        });
        assert_eq!(calls, 7);
        assert_eq!(s.n, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.seconds();
        let b = sw.seconds();
        assert!(b >= a);
    }
}
