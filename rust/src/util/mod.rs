//! Small shared infrastructure: deterministic PRNGs, timing helpers, and
//! text-report formatting used by the benchmark harness.
//!
//! The vendored crate set has no `rand`, so [`rng`] implements the
//! splitmix64 / xoshiro256** generators from scratch (public-domain
//! reference algorithms by Blackman & Vigna). All experiments seed
//! explicitly, making every table and figure bit-reproducible.

pub mod json;
pub mod mmap;
pub mod report;
pub mod rng;
pub mod timing;

pub use json::Json;
pub use mmap::{Mmap, Pod, PodVec};
pub use rng::Xoshiro256;
pub use timing::Stopwatch;
