//! Minimal JSON parsing and serialization (the vendored crate set has no
//! `serde`; see DESIGN.md §1). Covers the full JSON grammar — objects,
//! arrays, strings with escapes, numbers, booleans, null — with the
//! restrictions the serving layer needs spelled out:
//!
//! - numbers are `f64` (integers round-trip exactly up to 2⁵³);
//! - object keys keep insertion order ([`Json::get`] is a linear scan,
//!   fine for the handful of keys an API body carries);
//! - serialization emits shortest-round-trip floats (`{:?}`), so an `f64`
//!   survives render → parse **bit-identically** (non-finite values render
//!   as `null` — JSON has no NaN/Inf);
//! - parse depth and input size are bounded to keep a hostile HTTP body
//!   from recursing the stack away.

use anyhow::{bail, Result};

/// Maximum nesting depth accepted by the parser.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as `f64`).
    Num(f64),
    /// A string (unescaped).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace rejected).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(value)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as a float (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer; rejects fractional parts,
    /// negatives and magnitudes above 2⁵³ (where `f64` loses exactness).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Compact serialization (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => out.push_str(&fmt_number(*x)),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Format a number: shortest round-trip for finite values (Rust's `{:?}`
/// float formatting), `null` for NaN/±Inf (JSON has no spelling for them).
fn fmt_number(x: f64) -> String {
    if !x.is_finite() {
        return "null".to_string();
    }
    format!("{x:?}")
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        bail!("nesting deeper than {MAX_DEPTH}");
    }
    skip_ws(bytes, pos);
    let Some(&c) = bytes.get(*pos) else { bail!("unexpected end of input") };
    match c {
        b'{' => parse_object(bytes, pos, depth),
        b'[' => parse_array(bytes, pos, depth),
        b'"' => Ok(Json::Str(parse_string(bytes, pos)?)),
        b't' => parse_keyword(bytes, pos, "true", Json::Bool(true)),
        b'f' => parse_keyword(bytes, pos, "false", Json::Bool(false)),
        b'n' => parse_keyword(bytes, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_number(bytes, pos),
        other => bail!("unexpected byte {:?} at {}", other as char, *pos),
    }
}

fn parse_keyword(bytes: &[u8], pos: &mut usize, word: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(value)
    } else {
        bail!("bad literal at byte {}", *pos)
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("ascii digits");
    match text.parse::<f64>() {
        Ok(x) if x.is_finite() => Ok(Json::Num(x)),
        _ => bail!("bad number {text:?} at byte {start}"),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(bytes[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = bytes.get(*pos) else { bail!("unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = bytes.get(*pos) else { bail!("unterminated escape") };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = parse_hex4(bytes, pos)?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair: require the low half
                            if bytes.get(*pos) == Some(&b'\\')
                                && bytes.get(*pos + 1) == Some(&b'u')
                            {
                                *pos += 2;
                                let lo = parse_hex4(bytes, pos)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("bad low surrogate");
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                bail!("lone high surrogate");
                            }
                        } else if (0xDC00..0xE000).contains(&hi) {
                            bail!("lone low surrogate");
                        } else {
                            hi
                        };
                        out.push(char::from_u32(code).ok_or_else(|| {
                            anyhow::anyhow!("bad unicode escape U+{code:04X}")
                        })?);
                    }
                    other => bail!("bad escape \\{}", other as char),
                }
            }
            c if c < 0x20 => bail!("raw control byte {c:#x} in string"),
            c if c < 0x80 => out.push(c as char),
            _ => {
                // multi-byte UTF-8: re-decode from the source slice
                let rest = std::str::from_utf8(&bytes[*pos - 1..])
                    .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?;
                let ch = rest.chars().next().expect("non-empty");
                out.push(ch);
                *pos += ch.len_utf8() - 1;
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > bytes.len() {
        bail!("truncated \\u escape");
    }
    let text = std::str::from_utf8(&bytes[*pos..*pos + 4])
        .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
    let v = u32::from_str_radix(text, 16).map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
    *pos += 4;
    Ok(v)
}

fn parse_array(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_object(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b'"') {
            bail!("expected object key at byte {}", *pos);
        }
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {}", *pos);
        }
        *pos += 1;
        let value = parse_value(bytes, pos, depth + 1)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

/// Convenience: build an object from `(key, value)` pairs.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: a string value.
pub fn str(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

/// Convenience: a numeric value.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "nul", "1 2", "{\"a\" 1}", "\"\\q\"", "\"unterminated",
            "{a: 1}", "[1,]",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = "line\nquote\"back\\slash\ttab\u{1F600}é";
        let rendered = Json::Str(original.to_string()).render();
        assert_eq!(Json::parse(&rendered).unwrap().as_str(), Some(original));
        // \u escapes decode, including surrogate pairs
        assert_eq!(
            Json::parse(r#""\u0041\ud83d\ude00""#).unwrap().as_str(),
            Some("A\u{1F600}")
        );
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate rejected");
    }

    #[test]
    fn floats_round_trip_bit_identically() {
        for x in [0.1, 1.0 / 3.0, 2.5e-17, 12345.6789, f64::MIN_POSITIVE, 1e300] {
            let rendered = Json::Num(x).render();
            let back = Json::parse(&rendered).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} → {rendered}");
        }
        assert_eq!(Json::Num(f64::NAN).render(), "null", "non-finite renders as null");
    }

    #[test]
    fn as_u64_rejects_lossy_values() {
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(7.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1e300).as_u64(), None);
        assert_eq!(Json::Str("7".into()).as_u64(), None);
    }

    #[test]
    fn object_render_preserves_order_and_reparses() {
        let v = obj(vec![("b", num(1.0)), ("a", Json::Arr(vec![str("x"), Json::Null]))]);
        let text = v.render();
        assert_eq!(text, r#"{"b":1.0,"a":["x",null]}"#);
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn depth_limit_enforced() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(20) + &"]".repeat(20);
        assert!(Json::parse(&ok).is_ok());
    }
}
