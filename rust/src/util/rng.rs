//! Deterministic pseudo-random number generation.
//!
//! Implements splitmix64 (seeding) and xoshiro256** (stream), the standard
//! public-domain generators, so that graph generation and workload sampling
//! are exactly reproducible across runs and across the Rust/Python layers.

/// splitmix64 step: used to expand a single `u64` seed into the 256-bit
/// xoshiro state, as recommended by the xoshiro authors.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high-quality, tiny state; all randomness in the
/// crate flows through this type.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed from a single `u64` via splitmix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform double in `[0, 1)` (53-bit mantissa construction).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)`. Uses Lemire's multiply-shift
    /// rejection method to avoid modulo bias.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound || low >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Geometric-skip sampling helper: returns the number of failures before
    /// the next success of a Bernoulli(p) process. Used by the O(E) sparse
    /// Erdős–Rényi generator (Batagelj–Brandes skipping).
    #[inline]
    pub fn next_geometric(&mut self, p: f64) -> usize {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        (u.ln() / (1.0 - p).ln()).floor() as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Xoshiro256::seeded(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Xoshiro256::seeded(5);
        for _ in 0..50 {
            let s = r.sample_distinct(100, 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&x| x < 100));
        }
    }

    #[test]
    fn geometric_matches_expectation() {
        let mut r = Xoshiro256::seeded(9);
        let p = 0.1;
        let n = 50_000;
        let mean = (0..n).map(|_| r.next_geometric(p) as f64).sum::<f64>() / n as f64;
        // E[failures before success] = (1-p)/p = 9
        assert!((mean - 9.0).abs() < 0.3, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
