//! Execution runtimes: the PJRT artifact path and the persistent worker
//! pool behind every sharded fan-out.
//!
//! - [`pool`] — long-lived worker threads with a submit/barrier fan-out
//!   (DESIGN.md §5); the native engine's sweeps, the sharded kernels and
//!   the bench harness all run on the process-wide [`pool::global`] pool
//!   instead of spawning scoped threads per call.
//! - PJRT: loads the AOT-compiled HLO-text artifacts produced by
//!   `python/compile/aot.py` and executes them on the CPU PJRT client via
//!   the `xla` crate — Python never runs on this path. Artifact flow (see
//!   DESIGN.md §2 at the repository root): `manifest.txt` →
//!   [`manifest::Manifest`] → `HloModuleProto::from_text_file` →
//!   `client.compile` → [`PjrtPprEngine`] iterating the step executable
//!   with buffer feedback, convergence policy owned by the caller (L3).

pub mod engine;
pub mod manifest;
pub mod pool;

pub use engine::PjrtPprEngine;
pub use manifest::{ArtifactSpec, Manifest};
pub use pool::WorkerPool;

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled PPR-step executable bound to the PJRT CPU client.
pub struct StepExecutable {
    /// The artifact this was compiled from.
    pub spec: ArtifactSpec,
    /// PJRT loaded executable.
    pub exe: xla::PjRtLoadedExecutable,
}

/// Wrapper around the process-wide PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform name (e.g. "cpu"), for diagnostics.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load_step(&self, dir: &Path, spec: &ArtifactSpec) -> Result<StepExecutable> {
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {}", spec.file))?;
        Ok(StepExecutable { spec: spec.clone(), exe })
    }

    /// Access the raw client (advanced uses).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}
