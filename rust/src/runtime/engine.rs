//! PJRT-backed PPR engine: drives the AOT step executable from the L3
//! request path, with the iteration loop, early-exit policy and graph
//! marshalling on the Rust side.

use super::{ArtifactSpec, Manifest, Runtime, StepExecutable};
use crate::graph::VertexId;
use crate::ppr::{PprConfig, PreparedGraph};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Graph stream marshalled to the static shapes of an artifact.
struct MarshalledGraph {
    x: Vec<i32>,
    y: Vec<i32>,
    val_fixed: Vec<i64>,
    val_float: Vec<f32>,
    dangling_fixed: Vec<i64>,
    dangling_float: Vec<f32>,
}

/// A PPR engine executing the AOT-compiled step on the PJRT CPU client.
pub struct PjrtPprEngine {
    step: StepExecutable,
    graph: MarshalledGraph,
    num_vertices: usize,
}

impl PjrtPprEngine {
    /// Load the artifact for `label` from `dir` and bind it to a prepared
    /// graph. The graph must fit the artifact's static shapes (|V| ≤
    /// artifact vertices, padded stream ≤ artifact edges).
    pub fn load(rt: &Runtime, dir: &Path, label: &str, graph: &PreparedGraph) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let spec = manifest
            .find(label)
            .with_context(|| format!("no artifact for precision {label}"))?
            .clone();
        Self::load_spec(rt, dir, &spec, graph)
    }

    /// Load a specific artifact spec.
    pub fn load_spec(
        rt: &Runtime,
        dir: &Path,
        spec: &ArtifactSpec,
        graph: &PreparedGraph,
    ) -> Result<Self> {
        if graph.num_vertices > spec.vertices {
            bail!(
                "graph has {} vertices but artifact is sized for {}",
                graph.num_vertices,
                spec.vertices
            );
        }
        if graph.sched().num_slots() > spec.edges {
            bail!(
                "graph stream has {} slots but artifact is sized for {}",
                graph.sched().num_slots(),
                spec.edges
            );
        }
        let step = rt.load_step(dir, spec)?;
        let graph = Self::marshal(spec, graph);
        Ok(Self { step, graph, num_vertices: spec.vertices })
    }

    /// Pad the prepared stream to the artifact's static edge length and
    /// quantize values for its dtype. Padding entries carry val = 0 and
    /// point at vertex 0 — they contribute nothing.
    fn marshal(spec: &ArtifactSpec, graph: &PreparedGraph) -> MarshalledGraph {
        let e = spec.edges;
        let mut x: Vec<i32> = graph.sched().x.iter().map(|&v| v as i32).collect();
        let mut y: Vec<i32> = graph.sched().y.iter().map(|&v| v as i32).collect();
        let mut val = graph.sched().val.clone();
        x.resize(e, 0);
        y.resize(e, 0);
        val.resize(e, 0.0);

        let val_fixed: Vec<i64> = if spec.dtype == "s64" {
            let fmt = crate::fixed::FixedFormat::paper(spec.frac_bits + 1);
            val.iter().map(|&v| fmt.quantize(v) as i64).collect()
        } else {
            Vec::new()
        };
        let val_float: Vec<f32> = if spec.dtype == "f32" {
            val.iter().map(|&v| v as f32).collect()
        } else {
            Vec::new()
        };

        let mut dangling_fixed = vec![0i64; spec.vertices];
        for &d in &graph.dangling_idx {
            dangling_fixed[d as usize] = 1;
        }
        let dangling_float: Vec<f32> = dangling_fixed.iter().map(|&d| d as f32).collect();
        MarshalledGraph { x, y, val_fixed, val_float, dangling_fixed, dangling_float }
    }

    /// The artifact spec in use.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.step.spec
    }

    /// Run PPR for a batch of exactly κ personalization vertices, driving
    /// the step executable `cfg.max_iterations` times (with optional
    /// early exit on the update norm). Returns scores dequantized to f64,
    /// vertex-major `scores[v*κ + k]`, plus iterations executed.
    pub fn run(&self, personalization: &[VertexId], cfg: &PprConfig) -> Result<(Vec<f64>, usize)> {
        let spec = &self.step.spec;
        if personalization.len() != spec.kappa {
            bail!("batch of {} requests, artifact has κ={}", personalization.len(), spec.kappa);
        }
        match spec.dtype.as_str() {
            "s64" => self.run_fixed(personalization, cfg),
            "f32" => self.run_float(personalization, cfg),
            other => bail!("unknown artifact dtype {other}"),
        }
    }

    fn run_fixed(&self, pers: &[VertexId], cfg: &PprConfig) -> Result<(Vec<f64>, usize)> {
        let spec = &self.step.spec;
        let (v, k) = (spec.vertices, spec.kappa);
        let one = 1i64 << spec.frac_bits;
        let ulp = 0.5f64.powi(spec.frac_bits as i32);

        let mut pers_m = vec![0i64; v * k];
        let mut p = vec![0i64; v * k];
        for (lane, &pv) in pers.iter().enumerate() {
            pers_m[pv as usize * k + lane] = 1;
            p[pv as usize * k + lane] = one;
        }

        let x_l = xla::Literal::vec1(&self.graph.x).reshape(&[spec.edges as i64])?;
        let y_l = xla::Literal::vec1(&self.graph.y).reshape(&[spec.edges as i64])?;
        let val_l = xla::Literal::vec1(&self.graph.val_fixed).reshape(&[spec.edges as i64])?;
        let dang_l = xla::Literal::vec1(&self.graph.dangling_fixed).reshape(&[v as i64])?;
        let pers_l = xla::Literal::vec1(&pers_m).reshape(&[v as i64, k as i64])?;

        let mut iterations = 0usize;
        for _ in 0..cfg.max_iterations {
            let p_l = xla::Literal::vec1(&p).reshape(&[v as i64, k as i64])?;
            let result = self.step.exe.execute::<&xla::Literal>(&[
                &x_l, &y_l, &val_l, &p_l, &dang_l, &pers_l,
            ])?[0][0]
                .to_literal_sync()?;
            let next: Vec<i64> = result.to_tuple1()?.to_vec()?;
            iterations += 1;
            let norm = l2_norm_i64(&p, &next, ulp, k);
            p = next;
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    break;
                }
            }
        }
        Ok((p.iter().map(|&w| w as f64 * ulp).collect(), iterations))
    }

    fn run_float(&self, pers: &[VertexId], cfg: &PprConfig) -> Result<(Vec<f64>, usize)> {
        let spec = &self.step.spec;
        let (v, k) = (spec.vertices, spec.kappa);
        let mut pers_m = vec![0f32; v * k];
        let mut p = vec![0f32; v * k];
        for (lane, &pv) in pers.iter().enumerate() {
            pers_m[pv as usize * k + lane] = 1.0;
            p[pv as usize * k + lane] = 1.0;
        }
        let x_l = xla::Literal::vec1(&self.graph.x).reshape(&[spec.edges as i64])?;
        let y_l = xla::Literal::vec1(&self.graph.y).reshape(&[spec.edges as i64])?;
        let val_l = xla::Literal::vec1(&self.graph.val_float).reshape(&[spec.edges as i64])?;
        let dang_l = xla::Literal::vec1(&self.graph.dangling_float).reshape(&[v as i64])?;
        let pers_l = xla::Literal::vec1(&pers_m).reshape(&[v as i64, k as i64])?;

        let mut iterations = 0usize;
        for _ in 0..cfg.max_iterations {
            let p_l = xla::Literal::vec1(&p).reshape(&[v as i64, k as i64])?;
            let result = self.step.exe.execute::<&xla::Literal>(&[
                &x_l, &y_l, &val_l, &p_l, &dang_l, &pers_l,
            ])?[0][0]
                .to_literal_sync()?;
            let next: Vec<f32> = result.to_tuple1()?.to_vec()?;
            iterations += 1;
            let norm = l2_norm_f32(&p, &next, k);
            p = next;
            if let Some(th) = cfg.convergence_threshold {
                if norm < th {
                    break;
                }
            }
        }
        Ok((p.iter().map(|&w| w as f64).collect(), iterations))
    }

    /// Number of vertices of the bound artifact.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }
}

fn l2_norm_i64(a: &[i64], b: &[i64], ulp: f64, kappa: usize) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64 * ulp;
        acc += d * d;
    }
    (acc / kappa as f64).sqrt()
}

fn l2_norm_f32(a: &[f32], b: &[f32], kappa: usize) -> f64 {
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    (acc / kappa as f64).sqrt()
}
