//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py`): one line per compiled PPR-step variant.

use crate::fixed::Precision;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One artifact row: a PPR step lowered for fixed static shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    /// Precision label ("20b".."26b", "f32").
    pub label: String,
    /// File name within the artifacts directory.
    pub file: String,
    /// Static vertex count |V|.
    pub vertices: usize,
    /// Padded edge-stream length.
    pub edges: usize,
    /// κ lanes.
    pub kappa: usize,
    /// Fractional bits (0 for f32).
    pub frac_bits: u32,
    /// Element dtype ("s64" or "f32").
    pub dtype: String,
}

impl ArtifactSpec {
    /// The precision this artifact implements.
    pub fn precision(&self) -> Option<Precision> {
        Precision::parse(&self.label)
    }
}

/// Parsed manifest: the artifact set plus the α they were synthesized with.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Damping factor baked into the step executables.
    pub alpha: f64,
    /// All artifact rows.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut alpha = crate::PAPER_ALPHA;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = t.split_whitespace().collect();
            if fields[0] == "alpha" {
                alpha = fields
                    .get(1)
                    .context("alpha line missing value")?
                    .parse()
                    .context("bad alpha")?;
                continue;
            }
            if fields.len() != 7 {
                bail!("manifest line {}: expected 7 fields, got {}", lineno + 1, fields.len());
            }
            artifacts.push(ArtifactSpec {
                label: fields[0].to_string(),
                file: fields[1].to_string(),
                vertices: fields[2].parse().context("vertices")?,
                edges: fields[3].parse().context("edges")?,
                kappa: fields[4].parse().context("kappa")?,
                frac_bits: fields[5].parse().context("frac_bits")?,
                dtype: fields[6].to_string(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest contains no artifacts");
        }
        Ok(Self { alpha, artifacts })
    }

    /// Load `manifest.txt` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    /// Find the artifact for a precision label.
    pub fn find(&self, label: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.label == label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
alpha 0.85
26b ppr_step_26b_v512_e1024_k4.hlo.txt 512 1024 4 25 s64
f32 ppr_step_f32_v512_e1024_k4.hlo.txt 512 1024 4 0 f32
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.alpha, 0.85);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("26b").unwrap();
        assert_eq!(a.vertices, 512);
        assert_eq!(a.frac_bits, 25);
        assert_eq!(a.precision(), Some(Precision::Fixed(26)));
        assert_eq!(m.find("f32").unwrap().precision(), Some(Precision::Float32));
        assert!(m.find("99b").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("26b file.hlo 512").is_err());
    }
}
