//! Persistent sharded worker pool — the execution substrate behind every
//! shard fan-out (DESIGN.md §5).
//!
//! The sharded engine fans work out across destination partitions many
//! times per request: three sweeps per PPR iteration in the unfused
//! engine, one fused sweep per iteration in the fused one, plus the
//! standalone kernel fan-outs of the bench harness and the CPU baseline.
//! Spawning scoped threads per fan-out costs tens of microseconds of
//! spawn/join each — `3 × iterations × shards` spawns per request in the
//! worst case. This module replaces those per-call spawns with one
//! process-wide pool of long-lived workers: a fan-out *submits* one task
//! per work item and *barriers* on a completion latch; the worker threads
//! persist across calls, so the steady-state spawn count is zero (see
//! [`WorkerPool::spawn_count`], which tests assert stays flat across
//! iterations).
//!
//! Protocol (submit/barrier):
//!
//! 1. [`WorkerPool::fan_out`] boxes one task per item up front (so no
//!    allocation happens between the first submission and the barrier),
//!    enqueues all but the first, and runs the first inline on the
//!    calling thread — the caller is one of the workers, so `shards`
//!    items need only `shards − 1` pool threads.
//! 2. Each task writes its result into a dedicated slot and counts down
//!    a latch; panics are caught and re-thrown on the caller *after* the
//!    barrier, so borrowed data never outlives a running task.
//! 3. While its latch is unresolved the caller *helps*: it pops and runs
//!    queued tasks — its own remainder or other fan-outs' — so
//!    concurrent fan-outs on the capped pool never serialize behind one
//!    another; it sleeps on the latch only once the queue is empty.
//!    Results are then collected in item order — the same order the
//!    serial fallback produces, so pooled and serial execution yield
//!    identical result words.
//!
//! Safety: tasks borrow the caller's stack (the closure, the result
//! slots, the latch). The borrow is sound because `fan_out` cannot return
//! before the latch barrier — the tasks are either finished or the caller
//! is still blocked — and every task counts the latch down exactly once,
//! panic or not.
//!
//! Workers are spawned lazily up to a cap (the `num_shards` default:
//! available parallelism, capped at 32) and live for the lifetime of the
//! pool; the process-wide [`global`] pool is never dropped. Small work
//! still runs inline via the `serial` flag, exactly like the old
//! scoped-thread fallback.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set for the lifetime of a pool worker thread: a fan-out issued
    /// from *inside* a pool task runs serially instead of re-entering the
    /// queue, so tasks can never block a worker on another task's latch
    /// (the classic nested-pool deadlock is impossible by construction).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// A type-erased unit of work. Tasks are `'static` from the queue's point
/// of view; `fan_out` upholds the real (shorter) lifetime with its
/// barrier — see the module docs.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// State shared between the submitting threads and the workers.
struct Shared {
    queue: Mutex<VecDeque<Task>>,
    task_ready: Condvar,
    shutdown: AtomicBool,
    /// Panics swallowed from detached [`WorkerPool::submit`] tasks — a
    /// crashing connection handler is survived, but never silently:
    /// `/metrics` exports this count (DESIGN.md §10).
    caught_panics: AtomicUsize,
}

/// What a panicking task leaves behind for the caller to re-throw.
type PanicPayload = Box<dyn std::any::Any + Send>;

/// Completion latch for one fan-out: counts outstanding tasks and wakes
/// the submitter when the last one finishes. The first panic payload is
/// kept and re-thrown by the caller after the barrier.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<PanicPayload>>,
}

impl Latch {
    fn new(count: usize) -> Self {
        Self { remaining: Mutex::new(count), done: Condvar::new(), panic: Mutex::new(None) }
    }

    fn count_down(&self) {
        let mut rem = self.remaining.lock().expect("latch lock");
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().expect("latch lock");
        while *rem > 0 {
            rem = self.done.wait(rem).expect("latch wait");
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().expect("latch lock") == 0
    }
}

/// RAII toggle of [`IN_POOL_WORKER`] for a caller that executes queued
/// tasks while waiting (help-first): any fan-out issued from inside a
/// helped task must degrade to serial exactly as on a pool worker.
struct WorkerFlagGuard(bool);

impl WorkerFlagGuard {
    fn set() -> Self {
        WorkerFlagGuard(IN_POOL_WORKER.with(|w| w.replace(true)))
    }
}

impl Drop for WorkerFlagGuard {
    fn drop(&mut self) {
        let prev = self.0;
        IN_POOL_WORKER.with(|w| w.set(prev));
    }
}

/// Send wrapper for a raw result-slot pointer. Each task receives a
/// distinct slot, so there is never more than one writer per slot, and
/// the latch barrier sequences all writes before the caller's reads.
struct Slot<R>(*mut Option<R>);
// SAFETY: the pointee is owned by the fan-out caller and each Slot aliases
// a distinct element; see the struct docs.
unsafe impl<R: Send> Send for Slot<R> {}

/// A pool of persistent worker threads with a submit/barrier fan-out.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Upper bound on worker threads (≈ the shard-count cap).
    max_workers: usize,
    /// Worker threads spawned so far — the "zero spawns per iteration"
    /// counter: once warm, fan-outs never move it.
    spawned: AtomicUsize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    /// Create a pool that will lazily spawn up to `max_workers` threads.
    /// No thread is spawned until a parallel fan-out needs one.
    pub fn new(max_workers: usize) -> Self {
        Self {
            shared: Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                task_ready: Condvar::new(),
                shutdown: AtomicBool::new(false),
                caught_panics: AtomicUsize::new(0),
            }),
            max_workers,
            spawned: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Worker threads spawned over the pool's lifetime. Steady state is
    /// flat: once enough workers exist for the widest fan-out seen, no
    /// amount of further iterations changes this number.
    pub fn spawn_count(&self) -> usize {
        self.spawned.load(Ordering::Acquire)
    }

    /// Maximum worker threads this pool may spawn.
    pub fn max_workers(&self) -> usize {
        self.max_workers
    }

    /// Panics swallowed from detached [`Self::submit`] tasks so far.
    pub fn caught_panics(&self) -> usize {
        self.shared.caught_panics.load(Ordering::Relaxed)
    }

    /// Spawn every worker up front (tests use this to make the spawn
    /// counter flat regardless of which fan-out runs first).
    pub fn prewarm(&self) {
        self.ensure_workers(self.max_workers);
    }

    fn ensure_workers(&self, wanted: usize) {
        let target = wanted.min(self.max_workers);
        // racing fan-outs may both observe a deficit, but the CAS hands
        // out distinct spawn slots so the cap is never exceeded
        loop {
            let cur = self.spawned.load(Ordering::Acquire);
            if cur >= target {
                return;
            }
            if self
                .spawned
                .compare_exchange(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue;
            }
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ppr-pool-{cur}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
            self.handles.lock().expect("pool handles").push(handle);
        }
    }

    /// Run one closure per work item and return the results in item
    /// order. `serial == true` (the small-work fallback) runs everything
    /// inline on the caller; otherwise the items are distributed over the
    /// persistent workers with the caller executing the first item
    /// itself. Pooled and serial execution produce identical result
    /// vectors; a panicking item panics the caller after all items have
    /// settled.
    pub fn fan_out<T, R, F>(&self, items: Vec<T>, serial: bool, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let nested = IN_POOL_WORKER.with(Cell::get);
        if serial || nested || n <= 1 || self.max_workers == 0 {
            return items.into_iter().map(f).collect();
        }
        self.ensure_workers(n - 1);

        let mut slots: Vec<Option<R>> = std::iter::repeat_with(|| None).take(n).collect();
        let latch = Latch::new(n);
        let fr = &f;
        let latch_ref = &latch;

        // Box every task before submitting any: after the first task is
        // queued the only thing that may unwind on this thread is the
        // latch barrier itself, so the borrowed stack cannot die early.
        let mut tasks: Vec<Task> = Vec::with_capacity(n);
        for (slot, item) in slots.iter_mut().zip(items) {
            let slot = Slot(slot as *mut Option<R>);
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                match std::panic::catch_unwind(AssertUnwindSafe(|| fr(item))) {
                    // SAFETY: distinct slot per task, caller blocked on
                    // the latch until after this write (module docs)
                    Ok(r) => unsafe { *slot.0 = Some(r) },
                    Err(payload) => {
                        let mut p = latch_ref.panic.lock().expect("panic slot");
                        p.get_or_insert(payload);
                    }
                }
                latch_ref.count_down();
            });
            // SAFETY: extends the closure's borrow lifetime to 'static for
            // the queue; the latch barrier below outlives every task, so
            // no borrow is dangling while a task can still run.
            tasks.push(unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Task>(task)
            });
        }

        let mut pending = tasks.into_iter();
        let first = pending.next().expect("n >= 2");
        {
            let mut q = self.shared.queue.lock().expect("pool queue");
            q.extend(pending);
        }
        // one wake-up per queued task (notify_all on an empty wait set is
        // cheap; workers that find the queue empty just re-park)
        self.shared.task_ready.notify_all();

        // The caller is worker #0: run its own first task, then —
        // help-first — keep executing queued tasks (its own remainder or
        // other fan-outs') while its latch is unresolved. Concurrent
        // fan-outs on the capped pool therefore stay work-conserving:
        // a blocked caller is never idle while any task is runnable.
        // Sleep on the latch only once the queue is empty.
        first();
        loop {
            if latch.is_done() {
                break;
            }
            let task = self.shared.queue.lock().expect("pool queue").pop_front();
            match task {
                Some(t) => {
                    let _worker = WorkerFlagGuard::set();
                    t();
                }
                None => {
                    latch.wait();
                    break;
                }
            }
        }

        if let Some(payload) = latch.panic.lock().expect("panic slot").take() {
            std::panic::resume_unwind(payload);
        }
        slots.into_iter().map(|s| s.expect("task wrote its slot")).collect()
    }

    /// Enqueue one detached task: it runs on some pool worker, the caller
    /// does not wait, and the task owns its data (`'static`) — unlike
    /// [`fan_out`](Self::fan_out) there is no barrier upholding shorter
    /// borrows. Used by the HTTP front door to hand accepted connections
    /// to a **dedicated** pool (long-lived connection handlers on the
    /// global compute pool would starve engine fan-outs). Tasks submitted
    /// after the pool started dropping may be discarded without running.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        // swallow unwinds here so a panicking detached task can never kill
        // a worker (worker_loop relies on tasks not unwinding) — but count
        // them, so crashed handlers are visible on /metrics
        let shared = self.shared.clone();
        let task: Task = Box::new(move || {
            if std::panic::catch_unwind(AssertUnwindSafe(f)).is_err() {
                shared.caught_panics.fetch_add(1, Ordering::Relaxed);
            }
        });
        if self.max_workers == 0 {
            // degenerate pool: run inline rather than queueing forever
            task();
            return;
        }
        self.ensure_workers(self.max_workers);
        {
            let mut q = self.shared.queue.lock().expect("pool queue");
            q.push_back(task);
        }
        self.shared.task_ready.notify_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // set the flag under the queue lock so a worker between its
            // empty-queue check and its wait cannot miss the wake-up
            let _q = self.shared.queue.lock().expect("pool queue");
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.task_ready.notify_all();
        let handles = std::mem::take(&mut *self.handles.lock().expect("pool handles"));
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL_WORKER.with(|w| w.set(true));
    loop {
        let task = {
            let mut q = shared.queue.lock().expect("pool queue");
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.task_ready.wait(q).expect("pool wait");
            }
        };
        // tasks never unwind (fan_out catches inside), so a worker
        // survives any workload
        task();
    }
}

/// The process-wide pool every engine fan-out routes through. Sized like
/// the default shard count (available parallelism, capped at 32) and
/// never dropped — workers are daemon threads for the process lifetime.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(crate::config::default_num_shards()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_item_order_serial_and_pooled() {
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..16).collect();
        let serial = pool.fan_out(items.clone(), true, |i| i * 3);
        let pooled = pool.fan_out(items, false, |i| i * 3);
        assert_eq!(serial, pooled);
        assert_eq!(pooled, (0..16).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_count_flat_after_warmup() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.spawn_count(), 0, "lazy: no fan-out, no threads");
        pool.fan_out(vec![1, 2, 3, 4], false, |i| i);
        let warm = pool.spawn_count();
        assert!(warm >= 1 && warm <= 3, "{warm}");
        for _ in 0..50 {
            pool.fan_out(vec![1, 2, 3, 4], false, |i| i + 1);
        }
        assert_eq!(pool.spawn_count(), warm, "steady state must not spawn");
    }

    #[test]
    fn serial_fallback_spawns_nothing() {
        let pool = WorkerPool::new(8);
        for _ in 0..10 {
            pool.fan_out(vec![1, 2, 3], true, |i| i);
        }
        assert_eq!(pool.spawn_count(), 0);
    }

    #[test]
    fn caps_at_max_workers() {
        let pool = WorkerPool::new(2);
        pool.fan_out((0..64).collect::<Vec<usize>>(), false, |i| i % 7);
        assert!(pool.spawn_count() <= 2);
        pool.prewarm();
        assert_eq!(pool.spawn_count(), 2);
    }

    #[test]
    fn borrowed_data_flows_through() {
        let pool = WorkerPool::new(4);
        let base: Vec<u64> = (0..100).collect();
        let out = pool.fan_out((0..10usize).collect(), false, |chunk| {
            base[chunk * 10..(chunk + 1) * 10].iter().sum::<u64>()
        });
        assert_eq!(out.iter().sum::<u64>(), base.iter().sum::<u64>());
    }

    #[test]
    fn panic_propagates_after_barrier() {
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.fan_out(vec![0usize, 1, 2, 3], false, |i| {
                if i == 2 {
                    panic!("task 2 exploded");
                }
                i
            })
        }));
        assert!(result.is_err(), "panic must cross the pool");
        // the pool is still usable afterwards
        let ok = pool.fan_out(vec![5usize, 6], false, |i| i);
        assert_eq!(ok, vec![5, 6]);
    }

    #[test]
    fn concurrent_fan_outs_share_the_pool() {
        let pool = Arc::new(WorkerPool::new(4));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let out = pool.fan_out(vec![t, t + 1, t + 2], false, |i| i * 2);
                        assert_eq!(out, vec![2 * t, 2 * t + 2, 2 * t + 4]);
                    }
                });
            }
        });
        assert!(pool.spawn_count() <= 4);
    }

    #[test]
    fn nested_fan_out_runs_serially_not_deadlocking() {
        // a task that itself fans out must complete (inner call degrades
        // to serial inside a worker), even when the pool is narrow
        let pool = Arc::new(WorkerPool::new(1));
        let inner = pool.clone();
        let out = pool.fan_out(vec![10usize, 20], false, move |i| {
            inner.fan_out(vec![i, i + 1], false, |j| j * 2).iter().sum::<usize>()
        });
        assert_eq!(out, vec![42, 82]);
    }

    #[test]
    fn detached_submit_runs_and_survives_panics() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // a panicking detached task must not take a worker down
        pool.submit(|| panic!("detached task exploded"));
        let c = counter.clone();
        pool.submit(move || {
            c.fetch_add(100, Ordering::SeqCst);
        });
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while counter.load(Ordering::SeqCst) != 108 {
            assert!(std::time::Instant::now() < deadline, "detached tasks never completed");
            std::thread::yield_now();
        }
        // fan_out still works on the same pool afterwards
        assert_eq!(pool.fan_out(vec![1, 2], false, |i| i * 2), vec![2, 4]);
        // the swallowed panic is counted, not silent (poll: the panicking
        // task may still be unwinding on a sibling worker)
        while pool.caught_panics() != 1 {
            assert!(std::time::Instant::now() < deadline, "caught panic never counted");
            std::thread::yield_now();
        }
    }

    #[test]
    fn global_pool_is_shared_and_capped() {
        let p = global();
        assert!(p.max_workers() >= 1);
        let out = p.fan_out(vec![1u32, 2, 3], false, |i| i * i);
        assert_eq!(out, vec![1, 4, 9]);
        assert!(p.spawn_count() <= p.max_workers());
    }
}
