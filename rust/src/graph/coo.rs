//! COO (Coordinate format) transition matrix — the storage layout of the
//! paper's streaming SpMV (§3, Fig. 1).
//!
//! Three equally-sized arrays hold, for each non-zero, its destination
//! coordinate `x`, source coordinate `y`, and value `val = 1/outdeg(y)`
//! (the transition probability of moving from `y` to `x`). Entries are
//! sorted by `x` so the FSM write-back stage (Alg. 2, step 4) sees
//! monotonically non-decreasing destination blocks — the property the
//! two-ping-pong-buffer design relies on.

use super::{Graph, VertexId};
use crate::fixed::FixedFormat;

/// COO transition matrix X = (D⁻¹A)ᵀ plus the dangling bitmap.
#[derive(Debug, Clone, PartialEq)]
pub struct CooMatrix {
    /// Number of vertices |V| (matrix is |V|×|V|).
    pub num_vertices: usize,
    /// Destination coordinate of each non-zero (row of X), sorted ascending.
    pub x: Vec<VertexId>,
    /// Source coordinate of each non-zero (column of X).
    pub y: Vec<VertexId>,
    /// Transition probability 1/outdeg(y), as f64 (quantized on demand).
    pub val: Vec<f64>,
    /// Dangling bitmap d̄: true where outdeg == 0.
    pub dangling: Vec<bool>,
}

impl CooMatrix {
    /// Build the PPR transition matrix from a directed graph: entry
    /// (x=dst, y=src) has value 1/outdeg(src); entries sorted by (x, y).
    pub fn from_graph(g: &Graph) -> Self {
        let deg = g.out_degrees();
        let mut entries: Vec<(VertexId, VertexId)> =
            g.edges.iter().map(|&(s, d)| (d, s)).collect();
        // Sort by destination then source: the stream order of the paper's
        // DRAM layout (aggregators exploit destination locality).
        entries.sort_unstable();
        let mut x = Vec::with_capacity(entries.len());
        let mut y = Vec::with_capacity(entries.len());
        let mut val = Vec::with_capacity(entries.len());
        for (dst, src) in entries {
            x.push(dst);
            y.push(src);
            val.push(1.0 / deg[src as usize] as f64);
        }
        Self { num_vertices: g.num_vertices, x, y, val, dangling: g.dangling() }
    }

    /// Number of stored non-zeros.
    pub fn num_edges(&self) -> usize {
        self.x.len()
    }

    /// Quantize the value array into raw fixed-point words.
    pub fn quantized_values(&self, fmt: &FixedFormat) -> Vec<u64> {
        fmt.quantize_slice(&self.val)
    }

    /// Values as f32 (for the F32 FPGA variant and the CPU baseline).
    pub fn values_f32(&self) -> Vec<f32> {
        self.val.iter().map(|&v| v as f32).collect()
    }

    /// Number of packets of `b` edges needed to stream the matrix
    /// (the last packet is padded in hardware; the iterator below pads
    /// with zero-valued entries pointing at vertex `x.last()`).
    pub fn num_packets(&self, b: usize) -> usize {
        self.num_edges().div_ceil(b)
    }

    /// Iterate over edge packets of size `b` (Alg. 2 step 1). The final
    /// packet is padded with zero-valued self-entries so hardware-shaped
    /// consumers always see full packets.
    pub fn packets(&self, b: usize) -> PacketIter<'_> {
        PacketIter { coo: self, b, next: 0 }
    }

    /// Check structural invariants (sortedness, id ranges, value ranges).
    /// Used by tests and by the loader on untrusted input.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_vertices;
        if self.x.len() != self.y.len() || self.x.len() != self.val.len() {
            return Err("coordinate arrays have mismatched lengths".into());
        }
        if self.dangling.len() != n {
            return Err("dangling bitmap length != |V|".into());
        }
        for i in 0..self.x.len() {
            if self.x[i] as usize >= n || self.y[i] as usize >= n {
                return Err(format!("entry {i} out of range"));
            }
            if i > 0 && self.x[i] < self.x[i - 1] {
                return Err(format!("x not sorted at {i}"));
            }
            if !(self.val[i] > 0.0 && self.val[i] <= 1.0) {
                return Err(format!("value {} out of (0,1] at {i}", self.val[i]));
            }
            if self.dangling[self.y[i] as usize] {
                return Err(format!("entry {i} sourced from dangling vertex"));
            }
        }
        Ok(())
    }

    /// Column sums of X (should be 1 for non-dangling sources): a
    /// stochasticity check used by property tests.
    pub fn column_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0; self.num_vertices];
        for i in 0..self.num_edges() {
            sums[self.y[i] as usize] += self.val[i];
        }
        sums
    }
}

/// A borrowed view of one edge packet (possibly padded).
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    /// Destination coordinates (length b).
    pub x: Vec<VertexId>,
    /// Source coordinates (length b).
    pub y: Vec<VertexId>,
    /// Values (length b; padding entries are 0.0).
    pub val: Vec<f64>,
}

/// Iterator over fixed-size edge packets.
pub struct PacketIter<'a> {
    coo: &'a CooMatrix,
    b: usize,
    next: usize,
}

impl<'a> Iterator for PacketIter<'a> {
    type Item = Packet;

    fn next(&mut self) -> Option<Packet> {
        let e = self.coo.num_edges();
        if self.next >= e {
            return None;
        }
        let lo = self.next;
        let hi = (lo + self.b).min(e);
        self.next = lo + self.b;
        let mut x: Vec<VertexId> = self.coo.x[lo..hi].to_vec();
        let mut y: Vec<VertexId> = self.coo.y[lo..hi].to_vec();
        let mut val: Vec<f64> = self.coo.val[lo..hi].to_vec();
        // Pad the tail packet with zero-valued entries targeting the last
        // real destination (contributes nothing, keeps shapes fixed).
        let pad_x = *x.last().unwrap();
        while x.len() < self.b {
            x.push(pad_x);
            y.push(0);
            val.push(0.0);
        }
        Some(Packet { x, y, val })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        // 1 -> 0, 2 -> 0, 0 -> 1  (vertex 3 dangling); mirrors Fig. 1 style
        Graph::new(4, vec![(1, 0), (2, 0), (0, 1)])
    }

    #[test]
    fn transition_values() {
        let coo = CooMatrix::from_graph(&tiny());
        assert_eq!(coo.num_edges(), 3);
        // sorted by destination: (0,1) (0,2) (1,0)
        assert_eq!(coo.x, vec![0, 0, 1]);
        assert_eq!(coo.y, vec![1, 2, 0]);
        assert_eq!(coo.val, vec![1.0, 1.0, 1.0]);
        coo.validate().unwrap();
    }

    #[test]
    fn column_sums_stochastic() {
        let g = Graph::new(3, vec![(0, 1), (0, 2), (1, 0), (2, 1)]);
        let coo = CooMatrix::from_graph(&g);
        let sums = coo.column_sums();
        for (v, s) in sums.iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-12, "col {v} sums to {s}");
        }
    }

    #[test]
    fn packets_pad_tail() {
        let coo = CooMatrix::from_graph(&tiny());
        let packets: Vec<_> = coo.packets(2).collect();
        assert_eq!(packets.len(), 2);
        assert_eq!(packets[0].x, vec![0, 0]);
        assert_eq!(packets[1].x.len(), 2);
        assert_eq!(packets[1].val[1], 0.0); // padding entry
        assert_eq!(coo.num_packets(2), 2);
    }

    #[test]
    fn validate_catches_unsorted() {
        let mut coo = CooMatrix::from_graph(&tiny());
        coo.x.swap(0, 2);
        coo.y.swap(0, 2);
        assert!(coo.validate().is_err());
    }

    #[test]
    fn quantized_values_bounded() {
        let coo = CooMatrix::from_graph(&tiny());
        let fmt = FixedFormat::paper(20);
        let q = coo.quantized_values(&fmt);
        assert!(q.iter().all(|&w| w <= fmt.max_raw()));
        assert_eq!(q[0], fmt.one()); // 1/outdeg(1)=1.0 exact
    }
}
