//! CSR view of the transition matrix, used by the multi-threaded CPU
//! baseline (the PGX analogue) and by the CSR-vs-COO ablation bench.
//!
//! Rows are **destinations**: row `x` lists the sources `y` that link to
//! `x` with value `1/outdeg(y)`. A pull-based PPR iteration then writes
//! each output entry exactly once, which is what lets the CPU baseline
//! parallelize over row ranges with no atomics — the same reason the
//! paper's CSC discussion (§3) cares about who owns the write.

use super::{CooMatrix, Graph, VertexId};

/// CSR (by destination) transition matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Number of vertices.
    pub num_vertices: usize,
    /// Row pointer array, length |V|+1.
    pub row_ptr: Vec<usize>,
    /// Source vertex of each stored entry (column index).
    pub cols: Vec<VertexId>,
    /// Transition probability of each stored entry.
    pub vals: Vec<f64>,
    /// Dangling bitmap.
    pub dangling: Vec<bool>,
}

impl CsrMatrix {
    /// Build from a COO matrix (already sorted by destination).
    pub fn from_coo(coo: &CooMatrix) -> Self {
        let n = coo.num_vertices;
        let mut row_ptr = vec![0usize; n + 1];
        for &xi in &coo.x {
            row_ptr[xi as usize + 1] += 1;
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        Self {
            num_vertices: n,
            row_ptr,
            cols: coo.y.clone(),
            vals: coo.val.clone(),
            dangling: coo.dangling.clone(),
        }
    }

    /// Build directly from a graph.
    pub fn from_graph(g: &Graph) -> Self {
        Self::from_coo(&CooMatrix::from_graph(g))
    }

    /// Number of stored non-zeros.
    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    /// The (cols, vals) slice of one row (destination vertex).
    #[inline]
    pub fn row(&self, x: usize) -> (&[VertexId], &[f64]) {
        let lo = self.row_ptr[x];
        let hi = self.row_ptr[x + 1];
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Row lengths (in-degree of each destination).
    pub fn row_lengths(&self) -> Vec<usize> {
        (0..self.num_vertices).map(|i| self.row_ptr[i + 1] - self.row_ptr[i]).collect()
    }

    /// Split `[0, |V|)` into `parts` contiguous ranges with approximately
    /// equal numbers of non-zeros (not vertices) — the load-balancing the
    /// multi-threaded baseline needs on skewed-degree graphs. Delegates to
    /// [`super::partition::balanced_ranges_by`], the same partitioner the
    /// sharded streaming SpMV uses for its destination ranges, reading
    /// nnz counts straight from `row_ptr` (no weights allocation).
    pub fn balanced_ranges(&self, parts: usize) -> Vec<std::ops::Range<usize>> {
        super::partition::balanced_ranges_by(
            self.num_vertices,
            |v| self.row_ptr[v + 1] - self.row_ptr[v],
            parts,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn csr() -> CsrMatrix {
        // edges: 1->0, 2->0, 0->1 over 4 vertices (3 dangling)
        let g = Graph::new(4, vec![(1, 0), (2, 0), (0, 1)]);
        CsrMatrix::from_graph(&g)
    }

    #[test]
    fn structure() {
        let m = csr();
        assert_eq!(m.row_ptr, vec![0, 2, 3, 3, 3]);
        assert_eq!(m.cols, vec![1, 2, 0]);
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[1, 2]);
        assert_eq!(vals, &[1.0, 1.0]);
        assert_eq!(m.row(2).0.len(), 0);
        assert_eq!(m.num_edges(), 3);
    }

    #[test]
    fn row_lengths_match_in_degrees() {
        let g = Graph::new(4, vec![(1, 0), (2, 0), (0, 1)]);
        let m = CsrMatrix::from_graph(&g);
        let lens = m.row_lengths();
        let indeg: Vec<usize> = g.in_degrees().iter().map(|&d| d as usize).collect();
        assert_eq!(lens, indeg);
    }

    #[test]
    fn balanced_ranges_cover_all() {
        let m = csr();
        for parts in 1..5 {
            let ranges = m.balanced_ranges(parts);
            assert_eq!(ranges.len(), parts);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, m.num_vertices);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile");
            }
        }
    }

    #[test]
    fn balanced_ranges_balance_nnz() {
        // skewed: vertex 0 has many in-edges
        let mut edges = vec![];
        for s in 1..64u32 {
            edges.push((s, 0));
        }
        for s in 0..8u32 {
            edges.push((s, 64 + s));
        }
        let g = Graph::new(128, edges);
        let m = CsrMatrix::from_graph(&g);
        let ranges = m.balanced_ranges(4);
        let nnz: Vec<usize> =
            ranges.iter().map(|r| m.row_ptr[r.end] - m.row_ptr[r.start]).collect();
        // first range holds the hub; remaining ranges share the rest
        assert!(nnz[0] >= 63);
        assert_eq!(nnz.iter().sum::<usize>(), m.num_edges());
    }
}
