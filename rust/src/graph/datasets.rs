//! The paper's evaluation suite (Table 1): six synthetic graphs from three
//! statistical distributions at two sizes, plus the two real-world SNAP
//! datasets (Amazon co-purchasing, Twitter social circles).
//!
//! Real snapshots are loaded from `data/<name>.txt` when present; otherwise
//! structurally-matched synthetic stand-ins are generated (documented
//! substitution — see DESIGN.md §1): Amazon → Holme–Kim powerlaw-cluster
//! core (co-purchase clustering) topped up to the exact edge count;
//! Twitter → overlapping-community model (dense ego circles).

use super::generators;
use super::{Graph, VertexId};
use std::path::PathBuf;

/// The generator family of a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Distribution {
    /// Erdős–Rényi G(n,p).
    ErdosRenyi,
    /// Watts–Strogatz small-world.
    WattsStrogatz,
    /// Holme–Kim powerlaw-cluster.
    HolmeKim,
    /// Real-world: Amazon co-purchasing network (or stand-in).
    Amazon,
    /// Real-world: Twitter social circles (or stand-in).
    Twitter,
}

impl Distribution {
    /// True for the six synthetic rows of Table 1.
    pub fn is_synthetic(&self) -> bool {
        matches!(self, Self::ErdosRenyi | Self::WattsStrogatz | Self::HolmeKim)
    }
}

/// Specification of one Table 1 row.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Short name used in reports (e.g. "ER-100k", "AMZN").
    pub name: &'static str,
    /// Generator family.
    pub distribution: Distribution,
    /// Target vertex count.
    pub num_vertices: usize,
    /// Target edge count (exact; generators are trimmed/topped-up).
    pub num_edges: usize,
    /// Seed for deterministic generation.
    pub seed: u64,
}

/// A materialized dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The spec this dataset was built from.
    pub spec: DatasetSpec,
    /// The graph.
    pub graph: Graph,
}

impl DatasetSpec {
    /// The full 8-row Table 1 suite. `scale` divides both |V| and |E|
    /// (scale=1 is the paper's sizes; benches use scale>1 for quick runs).
    pub fn table1_suite(scale: usize) -> Vec<DatasetSpec> {
        assert!(scale >= 1);
        let s = |x: usize| (x / scale).max(64);
        vec![
            DatasetSpec {
                name: "ER-100k",
                distribution: Distribution::ErdosRenyi,
                num_vertices: s(100_000),
                num_edges: s(1_002_178),
                seed: 0xE401,
            },
            DatasetSpec {
                name: "ER-200k",
                distribution: Distribution::ErdosRenyi,
                num_vertices: s(200_000),
                num_edges: s(1_999_249),
                seed: 0xE402,
            },
            DatasetSpec {
                name: "WS-100k",
                distribution: Distribution::WattsStrogatz,
                num_vertices: s(100_000),
                num_edges: s(1_000_000),
                seed: 0xE403,
            },
            DatasetSpec {
                name: "WS-200k",
                distribution: Distribution::WattsStrogatz,
                num_vertices: s(200_000),
                num_edges: s(2_000_000),
                seed: 0xE404,
            },
            DatasetSpec {
                name: "HK-100k",
                distribution: Distribution::HolmeKim,
                num_vertices: s(100_000),
                num_edges: s(999_845),
                seed: 0xE405,
            },
            DatasetSpec {
                name: "HK-200k",
                distribution: Distribution::HolmeKim,
                num_vertices: s(200_000),
                num_edges: s(1_999_825),
                seed: 0xE406,
            },
            DatasetSpec {
                name: "AMZN",
                distribution: Distribution::Amazon,
                num_vertices: s(128_000),
                num_edges: s(443_378),
                seed: 0xE407,
            },
            DatasetSpec {
                name: "TWTR",
                distribution: Distribution::Twitter,
                num_vertices: s(81_306),
                num_edges: s(1_572_670),
                seed: 0xE408,
            },
        ]
    }

    /// The subset with ~2·10⁶ edges used by Fig. 4 (at the given scale:
    /// the three 200k-vertex synthetic graphs).
    pub fn fig4_suite(scale: usize) -> Vec<DatasetSpec> {
        Self::table1_suite(scale)
            .into_iter()
            .filter(|s| matches!(s.name, "ER-200k" | "WS-200k" | "HK-200k"))
            .collect()
    }

    /// Path where a real snapshot would live (`data/<name>.txt`).
    pub fn real_data_path(&self) -> Option<PathBuf> {
        match self.distribution {
            Distribution::Amazon => Some(PathBuf::from("data/amazon0302.txt")),
            Distribution::Twitter => Some(PathBuf::from("data/twitter_combined.txt")),
            _ => None,
        }
    }

    /// Materialize the graph. Real datasets load from disk when the SNAP
    /// snapshot is present; otherwise the documented stand-in is generated.
    /// All outputs are trimmed / topped-up to the exact target |E|.
    pub fn build(&self) -> Dataset {
        let n = self.num_vertices;
        let e = self.num_edges;
        let mut g = match self.distribution {
            Distribution::ErdosRenyi => {
                let p = e as f64 / (n as f64 * n as f64);
                let mut g = generators::erdos_renyi(n, p, self.seed);
                let have = g.num_edges();
                match have < e {
                    true => generators::add_random_edges(&mut g, e - have, self.seed ^ 1),
                    false => generators::trim_to_edge_count(&mut g, e, self.seed ^ 1),
                }
                g
            }
            Distribution::WattsStrogatz => {
                // |E| = n*k/2 per the directed-lattice convention.
                let k = ((2 * e) / n).max(2) & !1usize;
                let mut g = generators::watts_strogatz(n, k, 0.1, self.seed);
                let have = g.num_edges();
                match have < e {
                    true => generators::add_random_edges(&mut g, e - have, self.seed ^ 1),
                    false => generators::trim_to_edge_count(&mut g, e, self.seed ^ 1),
                }
                g
            }
            Distribution::HolmeKim => {
                let m = (e / n).max(1);
                let mut g = generators::holme_kim(n, m, 0.25, self.seed);
                let have = g.num_edges();
                match have < e {
                    true => generators::add_random_edges(&mut g, e - have, self.seed ^ 1),
                    false => generators::trim_to_edge_count(&mut g, e, self.seed ^ 1),
                }
                g
            }
            Distribution::Amazon => self.build_real_or(|spec| {
                // co-purchase graph: powerlaw-cluster core (m = 3) plus
                // uniform top-up to the exact edge count
                let m = (e / n).max(1);
                let mut g = generators::holme_kim(n, m, 0.5, spec.seed);
                let have = g.num_edges();
                if have < e {
                    generators::add_random_edges(&mut g, e - have, spec.seed ^ 1);
                } else {
                    generators::trim_to_edge_count(&mut g, e, spec.seed ^ 1);
                }
                g
            }),
            Distribution::Twitter => self.build_real_or(|spec| {
                // ego networks: overlapping dense communities
                let num_communities = (n / 100).max(8);
                generators::overlapping_communities(n, num_communities, 3, e, spec.seed)
            }),
        };
        g.simplify();
        // simplify() may drop a few duplicate edges produced by top-up;
        // restore the exact count so Table 1 reproduces row-for-row.
        let have = g.num_edges();
        if have < e {
            generators::add_random_edges(&mut g, e - have, self.seed ^ 2);
            g.edges.sort_unstable();
        }
        Dataset { spec: self.clone(), graph: g }
    }

    fn build_real_or<F: Fn(&DatasetSpec) -> Graph>(&self, fallback: F) -> Graph {
        if let Some(p) = self.real_data_path() {
            if p.exists() {
                if let Ok(g) = super::loader::read_edge_list(&p) {
                    return g;
                }
            }
        }
        fallback(self)
    }
}

impl Dataset {
    /// Sample `count` random non-dangling personalization vertices
    /// (the paper's "100 random personalization vertices" workload, §5.1).
    pub fn sample_personalization(&self, count: usize, seed: u64) -> Vec<VertexId> {
        let mut rng = crate::util::rng::Xoshiro256::seeded(seed);
        let dangling = self.graph.dangling();
        let candidates: Vec<VertexId> = (0..self.graph.num_vertices as VertexId)
            .filter(|&v| !dangling[v as usize])
            .collect();
        assert!(!candidates.is_empty(), "graph is all-dangling");
        (0..count).map(|_| candidates[rng.next_index(candidates.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_eight_rows() {
        let suite = DatasetSpec::table1_suite(1);
        assert_eq!(suite.len(), 8);
        assert_eq!(suite[0].name, "ER-100k");
        assert_eq!(suite[7].name, "TWTR");
    }

    #[test]
    fn scaled_build_hits_exact_edge_targets() {
        // scale 100 keeps the test fast but exercises every generator path
        for spec in DatasetSpec::table1_suite(100) {
            let ds = spec.build();
            assert_eq!(
                ds.graph.num_edges(),
                spec.num_edges,
                "{}: edges {} != target {}",
                spec.name,
                ds.graph.num_edges(),
                spec.num_edges
            );
            assert_eq!(ds.graph.num_vertices, spec.num_vertices, "{}", spec.name);
        }
    }

    #[test]
    fn builds_are_deterministic() {
        let spec = &DatasetSpec::table1_suite(200)[0];
        let a = spec.build();
        let b = spec.build();
        assert_eq!(a.graph, b.graph);
    }

    #[test]
    fn fig4_suite_is_the_2m_rows() {
        let s = DatasetSpec::fig4_suite(1);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|d| d.num_vertices == 200_000));
    }

    #[test]
    fn personalization_sampling_avoids_dangling() {
        let spec = &DatasetSpec::table1_suite(500)[4]; // HK
        let ds = spec.build();
        let dangling = ds.graph.dangling();
        for v in ds.sample_personalization(50, 99) {
            assert!(!dangling[v as usize]);
        }
    }
}
