//! Statistical graph generators reproducing the paper's synthetic suite
//! (Table 1): Erdős–Rényi G(n,p), Watts–Strogatz small-world, and Holme–Kim
//! powerlaw-cluster graphs, plus the community model used as a stand-in for
//! the Twitter ego-network dataset. Ports of the networkx algorithms the
//! paper used ("6 are generated using different statistical distributions
//! offered by the Python networkx library").

use super::{Graph, VertexId};
use crate::util::rng::Xoshiro256;
use std::collections::HashSet;

/// Directed Erdős–Rényi G(n, p) via Batagelj–Brandes geometric skipping:
/// O(|E|) instead of O(n²) Bernoulli trials. Self-loops excluded.
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    assert!(n > 0 && p > 0.0 && p < 1.0);
    let mut rng = Xoshiro256::seeded(seed);
    let mut edges = Vec::with_capacity((p * (n as f64) * (n as f64)) as usize);
    // Walk the flattened n*n adjacency with geometric jumps.
    let total = (n as u64) * (n as u64);
    let mut idx: u64 = rng.next_geometric(p) as u64;
    while idx < total {
        let s = (idx / n as u64) as VertexId;
        let d = (idx % n as u64) as VertexId;
        if s != d {
            edges.push((s, d));
        }
        idx += 1 + rng.next_geometric(p) as u64;
    }
    Graph::new(n, edges)
}

/// Watts–Strogatz small-world graph: ring lattice where each vertex
/// connects to its `k` nearest neighbours (`k/2` on each side), then each
/// edge is rewired with probability `p`. Edges are emitted in their lattice
/// orientation (one directed edge per lattice edge), matching the paper's
/// Table 1 count |E| = n·k/2 · 2 = n·k... the paper lists |E| = 10·n for
/// k = 20 half-edges; we emit one directed edge per (u, u+j) pair so
/// |E| = n·k/2.
pub fn watts_strogatz(n: usize, k: usize, p: f64, seed: u64) -> Graph {
    assert!(k % 2 == 0 && k < n, "k must be even and < n");
    let mut rng = Xoshiro256::seeded(seed);
    let mut present: HashSet<(VertexId, VertexId)> = HashSet::with_capacity(n * k / 2);
    for u in 0..n {
        for j in 1..=(k / 2) {
            let v = (u + j) % n;
            present.insert((u as VertexId, v as VertexId));
        }
    }
    // Rewire: replace (u, v) with (u, w) for uniform random w, avoiding
    // self-loops and duplicates (networkx `watts_strogatz_graph` semantics).
    let original: Vec<(VertexId, VertexId)> = {
        let mut v: Vec<_> = present.iter().copied().collect();
        v.sort_unstable();
        v
    };
    for (u, v) in original {
        if rng.next_bool(p) {
            // pick a new endpoint
            let mut w = rng.next_index(n) as VertexId;
            let mut attempts = 0;
            while (w == u || present.contains(&(u, w))) && attempts < 32 {
                w = rng.next_index(n) as VertexId;
                attempts += 1;
            }
            if w != u && !present.contains(&(u, w)) {
                present.remove(&(u, v));
                present.insert((u, w));
            }
        }
    }
    let mut edges: Vec<_> = present.into_iter().collect();
    edges.sort_unstable();
    Graph::new(n, edges)
}

/// Holme–Kim powerlaw-cluster graph: Barabási–Albert preferential
/// attachment of `m` edges per new vertex, with probability `pt` of closing
/// a triad after each attachment (networkx `powerlaw_cluster_graph`).
/// Produces the heavy-tailed degree distribution + dense communities the
/// paper highlights ("Holme and Kim graphs ... have dense communities,
/// similarly to real social networks").
pub fn holme_kim(n: usize, m: usize, pt: f64, seed: u64) -> Graph {
    assert!(m >= 1 && m < n);
    let mut rng = Xoshiro256::seeded(seed);
    // `repeated` holds one entry per half-edge endpoint: sampling uniformly
    // from it is sampling proportionally to degree.
    let mut repeated: Vec<VertexId> = Vec::with_capacity(2 * n * m);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(n * m);
    // adjacency as index-sampled Vecs: HashSet iteration order is
    // process-randomized and would break cross-run determinism
    let mut adjacency: Vec<Vec<VertexId>> = vec![Vec::new(); n];

    // seed clique over the first m vertices' stubs (networkx starts with m
    // isolated nodes and wires the first incomer to all of them)
    for v in 0..m {
        repeated.push(v as VertexId);
    }
    for source in m..n {
        let source = source as VertexId;
        let mut targets: Vec<VertexId> = Vec::with_capacity(m);
        let mut prev: Option<VertexId> = None;
        while targets.len() < m {
            // triad step: with prob pt, connect to a neighbour of the
            // previously chosen target (closes a triangle)
            let candidate = if let Some(pv) = prev.filter(|_| rng.next_bool(pt)) {
                let neigh = &adjacency[pv as usize];
                if neigh.is_empty() {
                    repeated[rng.next_index(repeated.len())]
                } else {
                    neigh[rng.next_index(neigh.len())]
                }
            } else {
                repeated[rng.next_index(repeated.len())]
            };
            if candidate != source && !targets.contains(&candidate) {
                targets.push(candidate);
                prev = Some(candidate);
            } else {
                prev = None;
            }
        }
        for &t in &targets {
            edges.push((source, t));
            adjacency[source as usize].push(t);
            adjacency[t as usize].push(source);
            repeated.push(source);
            repeated.push(t);
        }
    }
    edges.sort_unstable();
    Graph::new(n, edges)
}

/// Overlapping-community graph: the Twitter ego-network stand-in. Vertices
/// join `memberships` communities drawn from `num_communities` (sizes
/// heavy-tailed); each community is an Erdős–Rényi subgraph dense enough to
/// reach the target average degree. Produces the dense overlapping social
/// circles of the SNAP Twitter dataset.
pub fn overlapping_communities(
    n: usize,
    num_communities: usize,
    memberships: usize,
    target_edges: usize,
    seed: u64,
) -> Graph {
    let mut rng = Xoshiro256::seeded(seed);
    // Heavy-tailed community sizes: Zipf-ish weights.
    let mut members: Vec<Vec<VertexId>> = vec![Vec::new(); num_communities];
    for v in 0..n {
        for _ in 0..memberships {
            // zipf via inverse-power sampling
            let u = rng.next_f64();
            let c = ((num_communities as f64).powf(u) - 1.0) as usize % num_communities;
            members[c].push(v as VertexId);
        }
    }
    let mut present: HashSet<(VertexId, VertexId)> = HashSet::with_capacity(target_edges);
    let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(target_edges);
    // Round-robin the communities, sampling random intra-community pairs,
    // until we hit the target edge count.
    let mut guard = 0usize;
    let max_attempts = target_edges * 20;
    while edges.len() < target_edges && guard < max_attempts {
        guard += 1;
        let c = rng.next_index(num_communities);
        let com = &members[c];
        if com.len() < 2 {
            continue;
        }
        let a = com[rng.next_index(com.len())];
        let b = com[rng.next_index(com.len())];
        if a != b && !present.contains(&(a, b)) {
            present.insert((a, b));
            edges.push((a, b));
        }
    }
    // Top up with uniform random edges if communities saturated.
    while edges.len() < target_edges {
        let a = rng.next_index(n) as VertexId;
        let b = rng.next_index(n) as VertexId;
        if a != b && !present.contains(&(a, b)) {
            present.insert((a, b));
            edges.push((a, b));
        }
    }
    edges.sort_unstable();
    Graph::new(n, edges)
}

/// Add `extra` uniform-random distinct directed edges to a graph (used to
/// hit a dataset's exact |E| target, e.g. the Amazon stand-in).
pub fn add_random_edges(g: &mut Graph, extra: usize, seed: u64) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut present: HashSet<(VertexId, VertexId)> = g.edges.iter().copied().collect();
    let n = g.num_vertices;
    let mut added = 0usize;
    while added < extra {
        let a = rng.next_index(n) as VertexId;
        let b = rng.next_index(n) as VertexId;
        if a != b && !present.contains(&(a, b)) {
            present.insert((a, b));
            g.edges.push((a, b));
            added += 1;
        }
    }
}

/// Trim a graph to exactly `target` edges by dropping uniformly random
/// edges (keeps degree shape; used to pin dataset sizes).
pub fn trim_to_edge_count(g: &mut Graph, target: usize, seed: u64) {
    if g.edges.len() <= target {
        return;
    }
    let mut rng = Xoshiro256::seeded(seed);
    rng.shuffle(&mut g.edges);
    g.edges.truncate(target);
    g.edges.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erdos_renyi_edge_count_near_expectation() {
        let n = 2000;
        let p = 1e-3;
        let g = erdos_renyi(n, p, 42);
        let expect = p * (n * n) as f64;
        let got = g.num_edges() as f64;
        assert!((got - expect).abs() < 0.15 * expect, "got {got}, expect {expect}");
        assert!(g.edges.iter().all(|&(s, d)| s != d));
    }

    #[test]
    fn erdos_renyi_deterministic() {
        let a = erdos_renyi(500, 0.01, 7);
        let b = erdos_renyi(500, 0.01, 7);
        assert_eq!(a, b);
        let c = erdos_renyi(500, 0.01, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn watts_strogatz_edge_count_exact_at_p0() {
        let g = watts_strogatz(100, 10, 0.0, 1);
        assert_eq!(g.num_edges(), 100 * 5);
        // ring lattice: every vertex has out-degree k/2
        assert!(g.out_degrees().iter().all(|&d| d == 5));
    }

    #[test]
    fn watts_strogatz_rewiring_preserves_count() {
        let g = watts_strogatz(200, 10, 0.3, 3);
        // rewiring replaces edges 1:1 (up to rare saturation)
        let target = 200 * 5;
        assert!((g.num_edges() as i64 - target as i64).abs() <= 5);
    }

    #[test]
    fn holme_kim_edge_count() {
        let n = 1000;
        let m = 10;
        let g = holme_kim(n, m, 0.1, 5);
        assert_eq!(g.num_edges(), (n - m) * m);
    }

    #[test]
    fn holme_kim_heavy_tail() {
        let g = holme_kim(3000, 5, 0.3, 9);
        // undirected degree = in + out
        let deg: Vec<u32> = g
            .out_degrees()
            .iter()
            .zip(g.in_degrees())
            .map(|(a, b)| a + b)
            .collect();
        let max = *deg.iter().max().unwrap() as f64;
        let mean = deg.iter().map(|&d| d as f64).sum::<f64>() / deg.len() as f64;
        // preferential attachment: hubs far above the mean
        assert!(max > 8.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn overlapping_communities_hits_target() {
        let g = overlapping_communities(2000, 40, 2, 30_000, 11);
        assert_eq!(g.num_edges(), 30_000);
        assert!(g.edges.iter().all(|&(s, d)| s != d));
    }

    #[test]
    fn add_and_trim_edges() {
        let mut g = erdos_renyi(300, 0.005, 2);
        let before = g.num_edges();
        add_random_edges(&mut g, 100, 3);
        assert_eq!(g.num_edges(), before + 100);
        trim_to_edge_count(&mut g, before, 4);
        assert_eq!(g.num_edges(), before);
    }
}
