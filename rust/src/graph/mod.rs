//! Graph substrate: directed graphs, sparse-matrix views (COO/CSR), the
//! PPR transition matrix X = (D⁻¹A)ᵀ with dangling bitmap (§3 of the
//! paper), statistical generators matching the paper's Table 1 datasets,
//! a SNAP-format edge-list loader, and the nnz-balanced contiguous range
//! partitioning ([`partition`]) shared by the CSR CPU baseline and the
//! sharded streaming SpMV.

pub mod coo;
pub mod csr;
pub mod datasets;
pub mod generators;
pub mod loader;
pub mod partition;

pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use datasets::{Dataset, DatasetSpec, Distribution};

/// Vertex identifier. The paper's use-case caps at ~1M vertices (§4.1.2),
/// so 32 bits match the FPGA's packed 32-bit coordinate words.
pub type VertexId = u32;

/// A directed graph stored as an edge list (`src → dst`).
///
/// This is the neutral representation produced by generators and loaders;
/// algorithm-facing code converts it to [`CooMatrix`] (the streaming FPGA
/// layout) or [`CsrMatrix`] (the CPU baseline layout).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    /// Number of vertices |V| (ids are `0..num_vertices`).
    pub num_vertices: usize,
    /// Directed edges as (src, dst) pairs.
    pub edges: Vec<(VertexId, VertexId)>,
}

impl Graph {
    /// Build from parts, validating vertex ids.
    pub fn new(num_vertices: usize, edges: Vec<(VertexId, VertexId)>) -> Self {
        debug_assert!(
            edges.iter().all(|&(s, d)| (s as usize) < num_vertices && (d as usize) < num_vertices),
            "edge endpoint out of range"
        );
        Self { num_vertices, edges }
    }

    /// Number of directed edges |E|.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Sparsity |E| / |V|² as reported in Table 1.
    pub fn sparsity(&self) -> f64 {
        self.edges.len() as f64 / (self.num_vertices as f64 * self.num_vertices as f64)
    }

    /// Out-degree of every vertex.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices];
        for &(s, _) in &self.edges {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degree of every vertex.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices];
        for &(_, d) in &self.edges {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Dangling bitmap d̄ (§3): `true` for vertices with no outgoing edges.
    pub fn dangling(&self) -> Vec<bool> {
        self.out_degrees().iter().map(|&d| d == 0).collect()
    }

    /// Number of dangling vertices.
    pub fn num_dangling(&self) -> usize {
        self.dangling().iter().filter(|&&d| d).count()
    }

    /// Remove duplicate edges and self-loops (generators may produce a
    /// handful; the transition matrix assumes simple graphs).
    pub fn simplify(&mut self) {
        self.edges.retain(|&(s, d)| s != d);
        self.edges.sort_unstable();
        self.edges.dedup();
    }

    /// Maximum out-degree (drives the smallest representable transition
    /// probability, relevant to quantization underflow analysis).
    pub fn max_out_degree(&self) -> u32 {
        self.out_degrees().into_iter().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        // 0 -> 1, 0 -> 2, 1 -> 2, 3 dangling
        Graph::new(4, vec![(0, 1), (0, 2), (1, 2)])
    }

    #[test]
    fn degrees() {
        let g = tiny();
        assert_eq!(g.out_degrees(), vec![2, 1, 0, 0]);
        assert_eq!(g.in_degrees(), vec![0, 1, 2, 0]);
        assert_eq!(g.dangling(), vec![false, false, true, true]);
        assert_eq!(g.num_dangling(), 2);
        assert_eq!(g.max_out_degree(), 2);
    }

    #[test]
    fn sparsity() {
        let g = tiny();
        assert!((g.sparsity() - 3.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn simplify_removes_dupes_and_loops() {
        let mut g = Graph::new(3, vec![(0, 1), (0, 1), (1, 1), (2, 0)]);
        g.simplify();
        assert_eq!(g.edges, vec![(0, 1), (2, 0)]);
    }
}
