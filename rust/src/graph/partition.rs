//! Weight-balanced contiguous range partitioning.
//!
//! Both parallel backends split the vertex axis `[0, |V|)` into contiguous
//! ranges whose *non-zero counts* (not vertex counts) are approximately
//! equal: the CSR CPU baseline assigns one range per thread, and the
//! sharded streaming SpMV assigns one destination range per compute unit
//! (the multi-CU model of the HBM Top-K SpMV follow-up paper). Contiguity
//! is what makes the parallelism synchronization-free — each range owns a
//! disjoint slice of the output vector — and on skewed-degree graphs
//! balancing by nnz instead of vertices is what keeps the ranges' work
//! comparable.

use std::ops::Range;

/// Split `[0, weights.len())` into `parts` contiguous ranges whose weight
/// sums are approximately equal (greedy fill to `⌈total/parts⌉`). Always
/// returns exactly `parts` ranges that tile the index space in order;
/// trailing ranges may be empty when there are fewer heavy indices than
/// parts.
pub fn balanced_ranges(weights: &[usize], parts: usize) -> Vec<Range<usize>> {
    balanced_ranges_by(weights.len(), |i| weights[i], parts)
}

/// Like [`balanced_ranges`], but reading weights through a lookup — lets
/// callers that already hold a prefix-sum form (e.g. a CSR `row_ptr`)
/// partition without materializing a weights array.
pub fn balanced_ranges_by<W>(len: usize, weight: W, parts: usize) -> Vec<Range<usize>>
where
    W: Fn(usize) -> usize,
{
    assert!(parts > 0);
    let total: usize = (0..len).map(&weight).sum();
    let per = total.div_ceil(parts).max(1);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..len {
        acc += weight(i);
        if acc >= per && out.len() + 1 < parts {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    out.push(start..len);
    while out.len() < parts {
        out.push(len..len);
    }
    out
}

/// Total weight of one range (convenience for reporting/tests).
pub fn range_weight(weights: &[usize], r: &Range<usize>) -> usize {
    weights[r.clone()].iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_in_order() {
        let w = vec![3usize, 1, 4, 1, 5, 9, 2, 6];
        for parts in 1..10 {
            let rs = balanced_ranges(&w, parts);
            assert_eq!(rs.len(), parts);
            assert_eq!(rs[0].start, 0);
            assert_eq!(rs.last().unwrap().end, w.len());
            for pair in rs.windows(2) {
                assert_eq!(pair[0].end, pair[1].start, "ranges must tile");
            }
            let covered: usize = rs.iter().map(|r| range_weight(&w, r)).sum();
            assert_eq!(covered, w.iter().sum::<usize>());
        }
    }

    #[test]
    fn heavy_head_isolated() {
        // one dominant index fills its own range immediately; the light
        // tail (below the per-part target) shares the next range
        let mut w = vec![1usize; 16];
        w[0] = 100;
        let rs = balanced_ranges(&w, 4);
        assert_eq!(rs[0], 0..1);
        assert_eq!(rs[1], 1..16);
        assert_eq!(range_weight(&w, &rs[0]), 100);
        assert_eq!(range_weight(&w, &rs[1]), 15);
    }

    #[test]
    fn more_parts_than_weight_yields_empty_tails() {
        let w = vec![0usize, 0, 1];
        let rs = balanced_ranges(&w, 5);
        assert_eq!(rs.len(), 5);
        assert_eq!(rs.last().unwrap(), &(3..3));
        assert_eq!(rs.iter().map(|r| range_weight(&w, r)).sum::<usize>(), 1);
    }

    #[test]
    fn empty_weights() {
        let rs = balanced_ranges(&[], 3);
        assert_eq!(rs, vec![0..0, 0..0, 0..0]);
    }
}
