//! SNAP-format edge-list I/O. The paper's two real-world datasets come
//! from the Stanford Large Network Dataset Collection; this loader reads
//! their plain-text format (`# comment` lines, then `src<ws>dst` per line)
//! so real snapshots drop in directly when available. The dataset suite
//! falls back to synthetic stand-ins otherwise (see `datasets`).

use super::{Graph, VertexId};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Parse the `|V|=N |E|=M` size hint from a metadata comment (the header
/// [`write_edge_list`] emits). Either count may appear alone.
pub fn parse_size_hint(comment: &str) -> (Option<usize>, Option<usize>) {
    let grab = |tag: &str| -> Option<usize> {
        let rest = &comment[comment.find(tag)? + tag.len()..];
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        digits.parse().ok()
    };
    (grab("|V|="), grab("|E|="))
}

/// Read a SNAP-style edge list. Vertex ids are remapped to a dense
/// `0..|V|` range (SNAP files use sparse original ids). A metadata
/// comment carrying `|V|=N |E|=M` (as written by [`write_edge_list`])
/// pre-sizes the remap table and edge vector, so re-reading our own
/// output never rehashes or regrows mid-load.
///
/// Parsing streams through **one reused line buffer** (`read_line` into a
/// cleared `String`) instead of `lines()`, which allocates a fresh
/// `String` per line — on a multi-million-edge snapshot that is millions
/// of short-lived heap allocations for bytes the parser only borrows.
pub fn read_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut reader = BufReader::new(f);
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let mut sized = false;
    let intern = |raw: u64, remap: &mut HashMap<u64, VertexId>| -> VertexId {
        let next = remap.len() as VertexId;
        *remap.entry(raw).or_insert(next)
    };
    let mut line = String::new();
    let mut lineno = 0usize;
    loop {
        line.clear();
        let n = reader
            .read_line(&mut line)
            .with_context(|| format!("line {}: read error", lineno + 1))?;
        if n == 0 {
            break;
        }
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            // a size hint in the preamble pre-sizes both containers; the
            // hint is untrusted input, so clamp it — an absurd count must
            // not turn into an allocation-failure abort
            if !sized && edges.is_empty() {
                const MAX_HINT: usize = 1 << 20;
                let (v, e) = parse_size_hint(t);
                if let Some(v) = v {
                    remap.reserve(v.min(MAX_HINT));
                }
                if let Some(e) = e {
                    edges.reserve(e.min(MAX_HINT));
                }
                sized = v.is_some() || e.is_some();
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("line {lineno}: expected `src dst`"),
        };
        let a: u64 = a
            .parse()
            .with_context(|| format!("line {lineno}: bad src id {a:?} (integer overflow?)"))?;
        let b: u64 = b
            .parse()
            .with_context(|| format!("line {lineno}: bad dst id {b:?} (integer overflow?)"))?;
        let s = intern(a, &mut remap);
        let d = intern(b, &mut remap);
        if remap.len() > VertexId::MAX as usize {
            bail!(
                "line {}: more than {} distinct vertex ids (VertexId overflow)",
                lineno,
                VertexId::MAX
            );
        }
        edges.push((s, d));
    }
    if edges.is_empty() {
        bail!("{}: no edges", path.display());
    }
    Ok(Graph::new(remap.len(), edges))
}

/// Write a graph as a SNAP-style edge list (with a provenance header).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# ppr-spmv edge list: |V|={} |E|={}", g.num_vertices, g.num_edges())?;
    for &(s, d) in &g.edges {
        writeln!(f, "{s}\t{d}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (3, 0)]);
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test");
        let path = dir.join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path).unwrap();
        assert_eq!(g2.num_edges(), 3);
        assert_eq!(g2.num_vertices, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_comments_and_remaps_sparse_ids() {
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.txt");
        std::fs::write(&path, "# SNAP header\n1000 2000\n2000 1000\n1000 5\n").unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices, 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edges[0], (0, 1)); // 1000 -> 0, 2000 -> 1
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_hint_parses_writer_header() {
        assert_eq!(
            parse_size_hint("# ppr-spmv edge list: |V|=1234 |E|=56789"),
            (Some(1234), Some(56789))
        );
        assert_eq!(parse_size_hint("# |E|=7"), (None, Some(7)));
        assert_eq!(parse_size_hint("# SNAP header"), (None, None));
        assert_eq!(parse_size_hint("# |V|=x"), (None, None));
    }

    #[test]
    fn absurd_size_hint_does_not_allocate() {
        // the hint is clamped: a hostile header must not abort the process
        let dir = std::env::temp_dir().join("ppr_spmv_loader_hint_clamp");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("huge_hint.txt");
        std::fs::write(&path, "# |V|=1000000000000000 |E|=999999999999999\n0 1\n").unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices, 2);
        assert_eq!(g.num_edges(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_ids_round_trip_through_writer() {
        // sparse SNAP-style originals: remapped on read, then the written
        // form re-reads to the identical graph
        let dir = std::env::temp_dir().join("ppr_spmv_loader_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sparse.txt");
        std::fs::write(
            &path,
            "# SNAP header\n900000000 42\n42 900000000\n900000000 7\n7 123456\n",
        )
        .unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices, 4, "four distinct sparse ids");
        assert_eq!(g.num_edges(), 4);
        let rewritten = dir.join("dense.txt");
        write_edge_list(&g, &rewritten).unwrap();
        let text = std::fs::read_to_string(&rewritten).unwrap();
        assert!(text.starts_with("# ppr-spmv edge list: |V|=4 |E|=4"), "{text}");
        let g2 = read_edge_list(&rewritten).unwrap();
        // the writer emits already-dense ids in insertion order, so a
        // second read reproduces the graph exactly
        assert_eq!(g2, g);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn overflow_ids_report_line_number() {
        let dir = std::env::temp_dir().join("ppr_spmv_loader_overflow");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("overflow.txt");
        // line 3 carries an id that overflows u64
        std::fs::write(&path, "# header\n1 2\n99999999999999999999999999 3\n").unwrap();
        let err = format!("{:#}", read_edge_list(&path).unwrap_err());
        assert!(err.contains("line 3"), "{err}");
        assert!(err.contains("overflow"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_parse_handles_bulk_input() {
        // Throughput note: `read_edge_list` reuses a single line buffer, so
        // parsing N edges performs O(1) line allocations instead of O(N).
        // In a debug-build spot check this parses ~50k edges well under a
        // second; the point of the test is that a bulk file (many lines,
        // interleaved comments, no trailing newline) streams through the
        // reused-buffer loop correctly, not to time it.
        let dir = std::env::temp_dir()
            .join(format!("ppr-loader-bulk-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bulk.txt");
        let n = 50_000u64;
        let mut text = format!("# ppr-spmv edge list: |V|={} |E|={}\n", n + 1, n);
        for i in 0..n {
            if i % 10_000 == 0 {
                text.push_str("# periodic comment\n");
            }
            text.push_str(&format!("{} {}\n", i, i + 1));
        }
        text.pop(); // drop the trailing newline: last line ends at EOF
        std::fs::write(&path, &text).unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_edges(), n as usize);
        assert_eq!(g.num_vertices, n as usize + 1);
        assert_eq!(g.edges[0], (0, 1));
        assert_eq!(*g.edges.last().unwrap(), (n as u32 - 1, n as u32));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "not numbers\n").unwrap();
        assert!(read_edge_list(&path).is_err());
        std::fs::write(&path, "# only comments\n").unwrap();
        assert!(read_edge_list(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
