//! SNAP-format edge-list I/O. The paper's two real-world datasets come
//! from the Stanford Large Network Dataset Collection; this loader reads
//! their plain-text format (`# comment` lines, then `src<ws>dst` per line)
//! so real snapshots drop in directly when available. The dataset suite
//! falls back to synthetic stand-ins otherwise (see `datasets`).

use super::{Graph, VertexId};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Read a SNAP-style edge list. Vertex ids are remapped to a dense
/// `0..|V|` range (SNAP files use sparse original ids).
pub fn read_edge_list(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut remap: HashMap<u64, VertexId> = HashMap::new();
    let mut edges: Vec<(VertexId, VertexId)> = Vec::new();
    let intern = |raw: u64, remap: &mut HashMap<u64, VertexId>| -> VertexId {
        let next = remap.len() as VertexId;
        *remap.entry(raw).or_insert(next)
    };
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("line {}: expected `src dst`", lineno + 1),
        };
        let a: u64 = a.parse().with_context(|| format!("line {}: bad src", lineno + 1))?;
        let b: u64 = b.parse().with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let s = intern(a, &mut remap);
        let d = intern(b, &mut remap);
        edges.push((s, d));
    }
    if edges.is_empty() {
        bail!("{}: no edges", path.display());
    }
    Ok(Graph::new(remap.len(), edges))
}

/// Write a graph as a SNAP-style edge list (with a provenance header).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# ppr-spmv edge list: |V|={} |E|={}", g.num_vertices, g.num_edges())?;
    for &(s, d) in &g.edges {
        writeln!(f, "{s}\t{d}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (3, 0)]);
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test");
        let path = dir.join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path).unwrap();
        assert_eq!(g2.num_edges(), 3);
        assert_eq!(g2.num_vertices, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_comments_and_remaps_sparse_ids() {
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.txt");
        std::fs::write(&path, "# SNAP header\n1000 2000\n2000 1000\n1000 5\n").unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.num_vertices, 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edges[0], (0, 1)); // 1000 -> 0, 2000 -> 1
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("ppr_spmv_loader_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "not numbers\n").unwrap();
        assert!(read_edge_list(&path).is_err());
        std::fs::write(&path, "# only comments\n").unwrap();
        assert!(read_edge_list(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
