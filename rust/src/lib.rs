//! # ppr-spmv
//!
//! A reproduction of *"A reduced-precision streaming SpMV architecture for
//! Personalized PageRank on FPGA"* (Parravicini, Sgherzi, Santambrogio, 2020)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L1** (build-time Python): the paper's COO SpMV hot loop as a Pallas
//!   kernel with bit-accurate fixed-point arithmetic (`python/compile/kernels/`).
//! - **L2** (build-time Python): one Personalized PageRank iteration (Eq. 1 of
//!   the paper) in JAX, AOT-lowered to HLO text artifacts (`python/compile/`).
//! - **L3** (this crate): the serving coordinator, the bit-identical native
//!   fixed-point engine used for paper-scale experiments, the FPGA
//!   performance/resource/power simulator, graph substrates, ranking metrics,
//!   and the benchmark harness that regenerates every table and figure of the
//!   paper's evaluation.
//!
//! See `DESIGN.md` (repository root) for the system inventory, the
//! dependency policy (§1), the AOT artifact flow (§2) and the serving
//! engine API (§3); `bench_harness` regenerates the paper-vs-measured
//! numbers.

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod fixed;
pub mod fpga;
pub mod graph;
pub mod metrics;
pub mod ppr;
pub mod runtime;
pub mod serve;
pub mod spmv;
pub mod testutil;
pub mod util;

/// Library version (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Damping factor used throughout the paper's evaluation (§5.1).
pub const PAPER_ALPHA: f64 = 0.85;

/// Iteration count used for the paper's timed experiments (§5.1).
pub const PAPER_ITERATIONS: usize = 10;

/// Number of personalization vertices batched per pass (κ, §3).
pub const PAPER_KAPPA: usize = 8;

/// Edges processed per clock cycle (B, §4.1: 256-bit packets / 32-bit values).
pub const PAPER_B: usize = 8;

/// Personalization vertices per timed workload (§5.1: "100 random vertices").
pub const PAPER_WORKLOAD_VERTICES: usize = 100;
