//! END-TO-END THREE-LAYER DRIVER — proves all layers compose on a real
//! workload:
//!
//!   L1 Pallas COO-SpMV kernel  →  L2 JAX PPR step  →  `make artifacts`
//!   (HLO text)  →  L3 rust: PJRT load/compile  →  serving coordinator
//!   with dynamic batching  →  batched recommendation queries  →
//!   latency/throughput report + numeric cross-check vs the native
//!   bit-accurate engine.
//!
//! Requires `make artifacts` (skips politely otherwise).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt_serving
//! ```

use ppr_spmv::config::RunConfig;
use ppr_spmv::coordinator::engine::{PjrtEngineAdapter, ThreadBoundEngine};
use ppr_spmv::coordinator::{PprEngine, Server, ServerConfig};
use ppr_spmv::graph::generators;
use ppr_spmv::ppr::PreparedGraph;
use ppr_spmv::runtime::{Manifest, PjrtPprEngine, Runtime};
use ppr_spmv::util::{rng::Xoshiro256, Stopwatch};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts/manifest.txt missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let manifest = Manifest::load(&dir).expect("manifest parses");
    let spec = manifest.find("26b").expect("26b artifact").clone();
    println!(
        "artifact: {} (V={} E={} κ={} Q1.{})",
        spec.file, spec.vertices, spec.edges, spec.kappa, spec.frac_bits
    );

    // a product-graph exactly matching the artifact's static |V|
    let graph = generators::holme_kim(spec.vertices, 3, 0.4, 0xE2E);
    let pg = Arc::new(PreparedGraph::new(&graph, 8));
    println!(
        "graph: |V|={} |E|={} → {} stream slots (artifact capacity {})",
        graph.num_vertices,
        graph.num_edges(),
        pg.sched.num_slots(),
        spec.edges
    );

    let cfg = RunConfig {
        kappa: spec.kappa,
        iterations: 10,
        alpha: manifest.alpha,
        ..Default::default()
    };

    // L3: PJRT engines are thread-affine → pin each to its own thread
    let workers = 2;
    let engines: Vec<Box<dyn PprEngine>> = (0..workers)
        .map(|_| {
            let dir = dir.clone();
            let spec = spec.clone();
            let pg = pg.clone();
            let cfg = cfg.clone();
            let nv = graph.num_vertices;
            Box::new(
                ThreadBoundEngine::spawn(move || {
                    let rt = Runtime::cpu()?;
                    println!("  worker PJRT client up ({})", rt.platform());
                    let engine = PjrtPprEngine::load_spec(&rt, Path::new(&dir), &spec, &pg)?;
                    Ok(Box::new(PjrtEngineAdapter::new(engine, &cfg, nv)) as Box<_>)
                })
                .expect("engine thread"),
            ) as Box<dyn PprEngine>
        })
        .collect();

    let server = Server::start(
        engines,
        ServerConfig { batch_timeout: Duration::from_millis(10), default_top_n: 10 },
    );
    println!("serving via PJRT with {workers} workers, κ={} dynamic batching\n", spec.kappa);

    // real small workload: 64 batched recommendation queries
    let dangling = graph.dangling();
    let candidates: Vec<u32> =
        (0..graph.num_vertices as u32).filter(|&v| !dangling[v as usize]).collect();
    let mut rng = Xoshiro256::seeded(1);
    let sw = Stopwatch::start();
    let receivers: Vec<_> = (0..64)
        .map(|_| {
            let v = candidates[rng.next_index(candidates.len())];
            (v, server.submit(v, 10))
        })
        .collect();
    let mut responses = Vec::new();
    for (v, rx) in receivers {
        let resp = rx.recv().expect("server alive").expect("query succeeds");
        assert_eq!(resp.ranking[0].vertex, v, "personalization vertex ranks first");
        responses.push(resp);
    }
    let secs = sw.seconds();
    let snap = server.stats().snapshot();
    println!("completed {} queries in {:.3}s = {:.1} req/s", responses.len(), secs, 64.0 / secs);
    println!(
        "latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms | batches {} | mean fill {:.2}",
        snap.latency_p50_ms, snap.latency_p95_ms, snap.latency_p99_ms, snap.batches,
        snap.mean_batch_fill
    );

    // numeric cross-check: the PJRT path must agree with the native
    // bit-accurate engine on a fresh query's full top-10
    let probe = candidates[0];
    let pjrt_resp = server.query(probe, 10).expect("probe query");
    let d = ppr_spmv::spmv::datapath::FixedPath::paper(spec.frac_bits + 1);
    let mut native = ppr_spmv::ppr::BatchedPpr::new(d, pg, spec.kappa, manifest.alpha);
    let batch = ppr_spmv::ppr::batch_requests(&[probe], spec.kappa).remove(0);
    let out = native.run(
        &batch,
        &ppr_spmv::ppr::PprConfig {
            alpha: manifest.alpha,
            max_iterations: 10,
            convergence_threshold: None,
        },
    );
    let native_scores: Vec<f64> =
        out.lane(0, spec.kappa).iter().map(|&w| d.fmt.to_f64(w)).collect();
    let native_top = ppr_spmv::metrics::top_n_indices_f64(&native_scores, 10);
    let pjrt_top: Vec<usize> = pjrt_resp.ranking.iter().map(|r| r.vertex as usize).collect();
    assert_eq!(pjrt_top, native_top, "PJRT and native engines must agree bit-exactly");
    println!("\ncross-check vs native engine: top-10 identical ✓  ({pjrt_top:?})");

    server.shutdown();
    println!("e2e OK — all three layers compose");
}
