//! END-TO-END THREE-LAYER DRIVER — proves all layers compose on a real
//! workload:
//!
//!   L1 Pallas COO-SpMV kernel  →  L2 JAX PPR step  →  `make artifacts`
//!   (HLO text)  →  L3 rust: PJRT load/compile via `EngineBuilder::pjrt`
//!   (thread-bound engines under the hood)  →  serving coordinator with
//!   dynamic batching  →  batched recommendation queries  →
//!   latency/throughput report + numeric cross-check vs the native
//!   bit-accurate engine.
//!
//! Requires `make artifacts` and a real `xla` crate (skips politely when
//! the artifacts are missing or the in-tree xla stub is linked).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt_serving
//! ```

use ppr_spmv::config::RunConfig;
use ppr_spmv::coordinator::{EngineBuilder, PprEngine, ScoreBlock};
use ppr_spmv::fixed::Precision;
use ppr_spmv::graph::generators;
use ppr_spmv::ppr::PreparedGraph;
use ppr_spmv::runtime::Manifest;
use ppr_spmv::util::{rng::Xoshiro256, Stopwatch};
use std::path::PathBuf;
use std::sync::Arc;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts/manifest.txt missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let manifest = Manifest::load(&dir).expect("manifest parses");
    let spec = manifest.find("26b").expect("26b artifact").clone();
    println!(
        "artifact: {} (V={} E={} κ={} Q1.{})",
        spec.file, spec.vertices, spec.edges, spec.kappa, spec.frac_bits
    );

    // a product-graph exactly matching the artifact's static |V|
    let graph = generators::holme_kim(spec.vertices, 3, 0.4, 0xE2E);
    let pg = Arc::new(PreparedGraph::new(&graph, 8));
    println!(
        "graph: |V|={} |E|={} → {} stream slots (artifact capacity {})",
        graph.num_vertices,
        graph.num_edges(),
        pg.sched().num_slots(),
        spec.edges
    );

    let cfg = RunConfig {
        precision: Precision::Fixed(spec.frac_bits + 1),
        kappa: spec.kappa,
        iterations: 10,
        alpha: manifest.alpha,
        batch_timeout_ms: 10,
        artifacts_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };

    // L3: one builder call — PJRT engines are thread-affine, so the
    // builder returns them pre-pinned to dedicated threads
    let workers = 2;
    let server = match EngineBuilder::pjrt()
        .config(cfg.clone())
        .artifact_label("26b")
        .serve(&graph, workers)
    {
        Ok(s) => s,
        Err(e) => {
            eprintln!("PJRT serving unavailable ({e:#}) — skipping e2e demo");
            std::process::exit(2);
        }
    };
    println!("serving via PJRT with {workers} workers, κ={} dynamic batching\n", spec.kappa);

    // real small workload: 64 batched recommendation queries
    let dangling = graph.dangling();
    let candidates: Vec<u32> =
        (0..graph.num_vertices as u32).filter(|&v| !dangling[v as usize]).collect();
    let mut rng = Xoshiro256::seeded(1);
    let sw = Stopwatch::start();
    let tickets: Vec<_> = (0..64)
        .map(|_| {
            let v = candidates[rng.next_index(candidates.len())];
            (v, server.submit(v, 10))
        })
        .collect();
    let mut responses = Vec::new();
    for (v, ticket) in tickets {
        let resp = ticket.wait().expect("query succeeds");
        assert_eq!(resp.ranking[0].vertex, v, "personalization vertex ranks first");
        responses.push(resp);
    }
    let secs = sw.seconds();
    let snap = server.stats().snapshot();
    println!("completed {} queries in {:.3}s = {:.1} req/s", responses.len(), secs, 64.0 / secs);
    println!(
        "latency p50/p95/p99 = {:.1}/{:.1}/{:.1} ms | batches {} | mean fill {:.2}",
        snap.latency_p50_ms, snap.latency_p95_ms, snap.latency_p99_ms, snap.batches,
        snap.mean_batch_fill
    );

    // numeric cross-check: the PJRT path must agree with the native
    // bit-accurate engine (same builder, different kind) on a fresh
    // query's full top-10
    let probe = candidates[0];
    let pjrt_resp = server.query(probe, 10).expect("probe query");
    let mut native = EngineBuilder::native()
        .config(cfg.clone())
        .build_prepared(pg)
        .expect("native engine");
    let mut block = ScoreBlock::new();
    native.run_batch(&[probe], &mut block).expect("native batch");
    let native_top: Vec<u32> = block.top_n(0, 10).iter().map(|r| r.vertex).collect();
    let pjrt_top: Vec<u32> = pjrt_resp.ranking.iter().map(|r| r.vertex).collect();
    assert_eq!(pjrt_top, native_top, "PJRT and native engines must agree bit-exactly");
    println!("\ncross-check vs native engine: top-10 identical ✓  ({pjrt_top:?})");

    server.shutdown();
    println!("e2e OK — all three layers compose");
}
