//! E-commerce recommendation serving — the paper's motivating use-case
//! ("recommended items for a given query on an e-commerce platform").
//!
//! Builds the Amazon co-purchasing stand-in, stands up the serving
//! coordinator through `EngineBuilder::serve` with κ-lane dynamic batching
//! over the 26-bit engine, fires a bursty ticketed workload (some requests
//! carrying deadlines), and reports latency percentiles, throughput and
//! batching efficiency.
//!
//! ```sh
//! cargo run --release --example recommend_products
//! ```

use ppr_spmv::config::RunConfig;
use ppr_spmv::coordinator::EngineBuilder;
use ppr_spmv::fixed::Precision;
use ppr_spmv::graph::DatasetSpec;
use ppr_spmv::util::{rng::Xoshiro256, Stopwatch};
use std::time::Duration;

fn main() {
    // the AMZN row of Table 1 at 1/8 scale (16k products, 55k co-purchases)
    let spec = DatasetSpec::table1_suite(8).into_iter().find(|s| s.name == "AMZN").unwrap();
    let ds = spec.build();
    println!(
        "catalog graph: |V|={} |E|={} (Amazon co-purchasing stand-in)",
        ds.graph.num_vertices,
        ds.graph.num_edges()
    );

    let cfg = RunConfig {
        precision: Precision::Fixed(26),
        kappa: 8,
        iterations: 10,
        top_n: 10,
        batch_timeout_ms: 4,
        ..Default::default()
    };
    let workers = 2;
    let server = EngineBuilder::native()
        .config(cfg.clone())
        .serve(&ds.graph, workers)
        .expect("server starts");
    println!("serving with {workers} workers, κ={} batching, 26-bit fixed point\n", cfg.kappa);

    // bursty workload: 200 "users" arriving in waves; every fourth request
    // carries a (generous) deadline to exercise the deadline path
    let dangling = ds.graph.dangling();
    let products: Vec<u32> =
        (0..ds.graph.num_vertices as u32).filter(|&v| !dangling[v as usize]).collect();
    let mut rng = Xoshiro256::seeded(99);
    let sw = Stopwatch::start();
    let mut tickets = Vec::new();
    for wave in 0..10 {
        for i in 0..20 {
            let product = products[rng.next_index(products.len())];
            let deadline =
                if i % 4 == 0 { Some(Duration::from_secs(5)) } else { None };
            tickets.push((product, server.submit_with(product, 10, deadline)));
        }
        if wave % 3 == 2 {
            std::thread::sleep(Duration::from_millis(2)); // burst gap
        }
    }
    let mut sample_shown = false;
    let mut ok = 0usize;
    for (product, ticket) in tickets {
        match ticket.wait() {
            Ok(resp) => {
                ok += 1;
                if !sample_shown {
                    println!("sample: customers viewing product {product} may also like:");
                    for r in resp.ranking.iter().skip(1).take(5) {
                        println!("  product {:>6}  (affinity {:.5})", r.vertex, r.score);
                    }
                    sample_shown = true;
                }
            }
            Err(e) => eprintln!("request failed: {e}"),
        }
    }
    let secs = sw.seconds();
    let snap = server.stats().snapshot();
    println!("\n{ok} recommendations in {secs:.3}s = {:.0} req/s", ok as f64 / secs);
    println!(
        "latency p50/p95/p99 = {:.2}/{:.2}/{:.2} ms | queue p50 {:.2} ms",
        snap.latency_p50_ms, snap.latency_p95_ms, snap.latency_p99_ms, snap.queue_p50_ms
    );
    println!(
        "batches {} | mean fill {:.2}/κ={} | deadline misses {} (the paper's single-pass κ-batching)",
        snap.batches, snap.mean_batch_fill, cfg.kappa, snap.deadline_misses
    );
    server.shutdown();
}
