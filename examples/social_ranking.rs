//! Social-network feed ranking — the paper's second motivating use-case
//! ("find recommended posts in a social network while users interact
//! with it"). Explores the accuracy/bit-width trade-off interactively:
//! ranks the social circle of several users on the Twitter stand-in at
//! every precision (one engine per design point, all built through the
//! unified `EngineBuilder`) and prints the IR metrics of §5.3, plus the
//! simulated FPGA deployment report for each design point.
//!
//! ```sh
//! cargo run --release --example social_ranking
//! ```

use ppr_spmv::config::RunConfig;
use ppr_spmv::coordinator::{EngineBuilder, PprEngine, ScoreBlock};
use ppr_spmv::fixed::Precision;
use ppr_spmv::fpga::FpgaConfig;
use ppr_spmv::graph::{CooMatrix, DatasetSpec};
use ppr_spmv::metrics;
use ppr_spmv::ppr::{reference, PreparedGraph};
use std::sync::Arc;

fn main() {
    // TWTR row of Table 1 at 1/8 scale: dense overlapping communities
    let spec = DatasetSpec::table1_suite(8).into_iter().find(|s| s.name == "TWTR").unwrap();
    let ds = spec.build();
    println!(
        "social graph: |V|={} |E|={} avg degree {:.1}",
        ds.graph.num_vertices,
        ds.graph.num_edges(),
        ds.graph.num_edges() as f64 / ds.graph.num_vertices as f64
    );

    let coo = CooMatrix::from_graph(&ds.graph);
    let prepared = Arc::new(PreparedGraph::from_coo(&coo, ppr_spmv::PAPER_B));
    let users = ds.sample_personalization(8, 0x50C1A1);
    println!("ranking feeds for users {users:?}\n");

    // converged ground truth per user
    let truth: Vec<Vec<f64>> = users
        .iter()
        .map(|&u| reference::ppr_f64(&coo, u, ppr_spmv::PAPER_ALPHA, 100, Some(1e-12)).scores)
        .collect();

    println!(
        "{:>5} | {:>8} {:>9} {:>7} | {:>9} {:>7} {:>7}",
        "width", "err@10", "edit@10", "ndcg", "clock", "power", "LUT"
    );
    let mut block = ScoreBlock::new();
    for p in Precision::paper_sweep() {
        let Precision::Fixed(_) = p else { continue };
        let cfg = RunConfig {
            precision: p,
            kappa: users.len(),
            iterations: ppr_spmv::PAPER_ITERATIONS,
            ..Default::default()
        };
        let mut engine = EngineBuilder::native()
            .config(cfg)
            .build_prepared(prepared.clone())
            .expect("engine builds");
        engine.run_batch(&users, &mut block).expect("batch runs");

        // aggregate §5.3 metrics over the batch
        let mut errors = 0.0;
        let mut edit = 0.0;
        let mut ndcg = 0.0;
        for (lane, gt) in truth.iter().enumerate() {
            let rep = metrics::accuracy_report(block.lane(lane), gt, 10);
            errors += rep.num_errors as f64;
            edit += rep.edit_distance as f64;
            ndcg += rep.ndcg;
        }
        let n = users.len() as f64;

        // what deploying this design point costs on the simulated U200
        let synth = FpgaConfig::sized_for(p, ds.graph.num_vertices).synthesize().unwrap();
        println!(
            "{:>5} | {:>8.1} {:>9.1} {:>6.1}% | {:>6.0}MHz {:>6.1}W {:>6.0}%",
            p.label(),
            errors / n,
            edit / n,
            ndcg / n * 100.0,
            synth.clock_mhz,
            synth.power_w,
            synth.resources.lut * 100.0,
        );
    }
    println!("\n(paper §5.3: 26 bits is near-perfect; 22–24 bits remain satisfactory)");
}
