//! Quickstart: generate a small graph, run reduced-precision Personalized
//! PageRank at every bit-width the paper evaluates through the unified
//! engine API, and compare the rankings against the converged f64
//! reference.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ppr_spmv::config::RunConfig;
use ppr_spmv::coordinator::{EngineBuilder, PprEngine, ScoreBlock};
use ppr_spmv::fixed::Precision;
use ppr_spmv::graph::{generators, CooMatrix};
use ppr_spmv::metrics;
use ppr_spmv::ppr::{reference, PreparedGraph};
use std::sync::Arc;

fn main() {
    // 1. a Holme–Kim powerlaw-cluster graph: the paper's stand-in for
    //    social-network community structure
    let g = generators::holme_kim(10_000, 8, 0.3, 7);
    println!(
        "graph: |V|={} |E|={} sparsity={:.1e}",
        g.num_vertices,
        g.num_edges(),
        g.sparsity()
    );

    // 2. preprocess once (COO transition matrix + aligned packet schedule),
    //    shared by every engine the builder constructs below
    let coo = CooMatrix::from_graph(&g);
    let prepared = Arc::new(PreparedGraph::from_coo(&coo, ppr_spmv::PAPER_B));
    println!(
        "stream: {} packets of B={} ({}% padding)",
        prepared.sched().num_packets(),
        prepared.sched().b,
        (prepared.sched().padding_overhead() * 100.0).round(),
    );

    // 3. ground truth: f64 PPR at convergence (the paper's CPU oracle)
    let pers: u32 = 4242;
    let truth = reference::ppr_f64(&coo, pers, ppr_spmv::PAPER_ALPHA, 100, Some(1e-12));
    let truth_top = metrics::top_n_indices_f64(&truth.scores, 10);
    println!("\nf64 reference top-10 for vertex {pers}: {truth_top:?}");

    // 4. reduced-precision PPR, 10 iterations, per bit-width — one
    //    single-lane partial batch on a κ=8 engine (lanes are independent,
    //    so a 1-request batch costs 1/8th of a full one)
    let mut block = ScoreBlock::new();
    for p in Precision::paper_sweep() {
        let Precision::Fixed(_) = p else { continue };
        let cfg = RunConfig {
            precision: p,
            kappa: ppr_spmv::PAPER_KAPPA,
            iterations: ppr_spmv::PAPER_ITERATIONS,
            ..Default::default()
        };
        let mut engine = EngineBuilder::native()
            .config(cfg)
            .build_prepared(prepared.clone())
            .expect("engine builds");
        engine.run_batch(&[pers], &mut block).expect("batch runs");
        let scores = block.lane(0);
        let rep = metrics::accuracy_report(scores, &truth.scores, 10);
        println!(
            "{:>4}: top-10 {:?}  errors={} edit={} ndcg={:.2}%",
            p.label(),
            metrics::top_n_indices_f64(scores, 10),
            rep.num_errors,
            rep.edit_distance,
            rep.ndcg * 100.0
        );
    }

    println!("\n(the paper's finding: >=22 bits preserves the ranking almost perfectly)");
}
